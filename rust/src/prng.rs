//! Deterministic pseudo-random number generation (no external `rand`).
//!
//! The workload generators and property tests need fast, seedable,
//! reproducible randomness. We implement SplitMix64 (for seeding) and
//! xoshiro256** (for the stream), the same generators the reference
//! `rand` ecosystem uses for non-cryptographic workloads.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the main PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 cannot produce four
        // consecutive zeros for any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (used by clustered workloads).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > f64::EPSILON {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.uniform(-5.0, 11.0);
            assert!((-5.0..11.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(1234);
        let mut c1 = base.fork();
        let mut c2 = base.fork();
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 3);
    }
}

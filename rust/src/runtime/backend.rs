//! The XLA matching backend: DDM matching on the AOT-compiled
//! JAX+Pallas kernels.
//!
//! This is the system's "accelerator path": the dense tiled matcher
//! (DESIGN.md §3, hardware adaptation of the paper's GPU remarks).
//! Inputs of arbitrary size are tiled over the compiled capacity and
//! padded with the kernels' PAD sentinel (`1e30`, half-open ⇒ padded
//! rows never match).
//!
//! Coordinates are converted f64 → f32; callers whose coordinates
//! exceed f32's 24-bit integer range should pre-scale (the HLA spec's
//! integer dimensions fit comfortably for upper bounds < 2²⁴).

use std::path::Path;

use crate::bail;
use crate::error::{Context, Result};

use super::loader::{ArtifactKind, LoadedArtifact, Runtime};
use crate::core::{Regions1D, RegionsNd};

pub use super::{quantize_f32, PAD};

/// DDM matching backed by compiled XLA executables.
pub struct XlaMatchBackend {
    rt: Runtime,
}

impl XlaMatchBackend {
    pub fn load(dir: &Path) -> Result<Self> {
        Ok(Self {
            rt: Runtime::load(dir)?,
        })
    }

    /// Capacities (n, m) of the counts artifact for dimension `d`.
    pub fn counts_capacity(&self, d: usize) -> Option<(usize, usize)> {
        self.rt
            .find(ArtifactKind::Counts, d)
            .map(|a| (a.meta.n, a.meta.m))
    }

    /// Pack one side's bounds for a tile: `[cap, d]` f32, PAD-filled.
    fn pack(
        regions: &RegionsNd,
        range: std::ops::Range<usize>,
        cap: usize,
        lower: bool,
    ) -> Vec<f32> {
        let d = regions.d();
        let mut out = vec![PAD; cap * d];
        for (row, i) in range.enumerate() {
            for (k, dim) in regions.dims.iter().enumerate() {
                out[row * d + k] = if lower {
                    dim.lo[i] as f32
                } else {
                    dim.hi[i] as f32
                };
            }
        }
        out
    }

    fn literal(data: &[f32], rows: usize, d: usize) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, d as i64])?)
    }

    /// Total intersection count via the tiled counts kernel.
    ///
    /// Tiles the (n × m) pair space over the compiled capacity; each
    /// tile is one PJRT execution. K = Σ tile totals.
    pub fn match_counts(&self, subs: &RegionsNd, upds: &RegionsNd) -> Result<u64> {
        let d = subs.d();
        if upds.d() != d {
            bail!("dimension mismatch: {} vs {}", d, upds.d());
        }
        let art = self
            .rt
            .find(ArtifactKind::Counts, d)
            .with_context(|| format!("no counts artifact for d={d}"))?;
        let (cap_n, cap_m) = (art.meta.n, art.meta.m);
        let mut total = 0u64;
        let mut i = 0;
        while i < subs.len().max(1) {
            let si = i..(i + cap_n).min(subs.len());
            let s_lo = Self::pack(subs, si.clone(), cap_n, true);
            let s_hi = Self::pack(subs, si.clone(), cap_n, false);
            let mut j = 0;
            while j < upds.len().max(1) {
                let uj = j..(j + cap_m).min(upds.len());
                let u_lo = Self::pack(upds, uj.clone(), cap_m, true);
                let u_hi = Self::pack(upds, uj.clone(), cap_m, false);
                total += self.run_counts_tile(art, &s_lo, &s_hi, &u_lo, &u_hi, d)?;
                j += cap_m;
                if upds.is_empty() {
                    break;
                }
            }
            i += cap_n;
            if subs.is_empty() {
                break;
            }
        }
        Ok(total)
    }

    fn run_counts_tile(
        &self,
        art: &LoadedArtifact,
        s_lo: &[f32],
        s_hi: &[f32],
        u_lo: &[f32],
        u_hi: &[f32],
        d: usize,
    ) -> Result<u64> {
        let (cap_n, cap_m) = (art.meta.n, art.meta.m);
        let args = [
            Self::literal(s_lo, cap_n, d)?,
            Self::literal(s_hi, cap_n, d)?,
            Self::literal(u_lo, cap_m, d)?,
            Self::literal(u_hi, cap_m, d)?,
        ];
        let result = art.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        // L2 lowers with return_tuple=True: (counts[n], total).
        let (_counts, total) = result.to_tuple2()?;
        let t: Vec<i32> = total.to_vec()?;
        Ok(t[0] as u64)
    }

    /// Enumerate intersecting pairs via the mask kernel (single tile —
    /// meant for coordinator batches up to the compiled capacity).
    pub fn match_pairs(
        &self,
        subs: &RegionsNd,
        upds: &RegionsNd,
    ) -> Result<Vec<(u32, u32)>> {
        let d = subs.d();
        let art = self
            .rt
            .find(ArtifactKind::Mask, d)
            .with_context(|| format!("no mask artifact for d={d}"))?;
        let (cap_n, cap_m) = (art.meta.n, art.meta.m);
        if subs.len() > cap_n || upds.len() > cap_m {
            bail!(
                "mask capacity exceeded: {}x{} > {}x{}",
                subs.len(),
                upds.len(),
                cap_n,
                cap_m
            );
        }
        let s_lo = Self::pack(subs, 0..subs.len(), cap_n, true);
        let s_hi = Self::pack(subs, 0..subs.len(), cap_n, false);
        let u_lo = Self::pack(upds, 0..upds.len(), cap_m, true);
        let u_hi = Self::pack(upds, 0..upds.len(), cap_m, false);
        let args = [
            Self::literal(&s_lo, cap_n, d)?,
            Self::literal(&s_hi, cap_n, d)?,
            Self::literal(&u_lo, cap_m, d)?,
            Self::literal(&u_hi, cap_m, d)?,
        ];
        let result = art.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mask = result.to_tuple1()?;
        let bytes: Vec<u8> = mask.to_vec()?;
        let mut pairs = Vec::new();
        for i in 0..subs.len() {
            let row = &bytes[i * cap_m..i * cap_m + upds.len()];
            for (j, &b) in row.iter().enumerate() {
                if b != 0 {
                    pairs.push((i as u32, j as u32));
                }
            }
        }
        Ok(pairs)
    }

    /// Run the compiled Fig.-7 prefix-sum pipeline (demo/validation).
    pub fn prefix_sum(&self, xs: &[i32]) -> Result<Vec<i32>> {
        let art = self
            .rt
            .find(ArtifactKind::Scan, 0)
            .context("no scan artifact")?;
        let cap = art.meta.n;
        if xs.len() > cap {
            bail!("scan capacity exceeded: {} > {cap}", xs.len());
        }
        let mut data = vec![0i32; cap];
        data[..xs.len()].copy_from_slice(xs);
        let lit = xla::Literal::vec1(&data);
        let result = art.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let scanned = result.to_tuple1()?;
        let out: Vec<i32> = scanned.to_vec()?;
        Ok(out[..xs.len()].to_vec())
    }

    /// 1-D convenience wrappers (benches use these).
    pub fn match_counts_1d(&self, subs: &Regions1D, upds: &Regions1D) -> Result<u64> {
        self.match_counts(&wrap_1d(subs), &wrap_1d(upds))
    }

    pub fn match_pairs_1d(
        &self,
        subs: &Regions1D,
        upds: &Regions1D,
    ) -> Result<Vec<(u32, u32)>> {
        self.match_pairs(&wrap_1d(subs), &wrap_1d(upds))
    }
}

fn wrap_1d(r: &Regions1D) -> RegionsNd {
    RegionsNd {
        dims: vec![r.clone()],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::bfm;
    use crate::core::interval::Interval;
    use crate::core::region::random_regions_1d;
    use crate::core::sink::{canonicalize, CountSink, VecSink};
    use crate::prng::Rng;

    fn backend() -> Option<XlaMatchBackend> {
        let dir = Path::new(crate::runtime::DEFAULT_ARTIFACT_DIR);
        if !crate::runtime::artifacts_available(dir) {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(XlaMatchBackend::load(dir).expect("backend loads"))
    }

    /// f32-exact random regions (backend computes in f32).
    fn q_regions(rng: &mut Rng, k: usize, space: f64, len: f64) -> Regions1D {
        quantize_f32(&random_regions_1d(rng, k, space, len))
    }

    #[test]
    fn counts_match_bfm_1d() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(0xA1A);
        let subs = q_regions(&mut rng, 300, 1000.0, 12.0);
        let upds = q_regions(&mut rng, 450, 1000.0, 12.0);
        let mut want = CountSink::default();
        bfm::match_seq(&subs, &upds, &mut want);
        let got = be.match_counts_1d(&subs, &upds).unwrap();
        assert_eq!(got, want.count);
    }

    #[test]
    fn counts_tile_across_capacity() {
        let Some(be) = backend() else { return };
        let (cap_n, cap_m) = be.counts_capacity(1).unwrap();
        // Exceed both capacities to force 4+ tiles.
        let mut rng = Rng::new(0xA1B);
        let subs = q_regions(&mut rng, cap_n + 17, 1e5, 30.0);
        let upds = q_regions(&mut rng, cap_m + 5, 1e5, 30.0);
        let mut want = CountSink::default();
        bfm::match_seq(&subs, &upds, &mut want);
        let got = be.match_counts_1d(&subs, &upds).unwrap();
        assert_eq!(got, want.count);
    }

    #[test]
    fn pairs_match_bfm_1d() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(0xA1C);
        let subs = q_regions(&mut rng, 64, 100.0, 5.0);
        let upds = q_regions(&mut rng, 80, 100.0, 5.0);
        let mut want = VecSink::default();
        bfm::match_seq(&subs, &upds, &mut want);
        let got = be.match_pairs_1d(&subs, &upds).unwrap();
        assert_eq!(canonicalize(got), canonicalize(want.pairs));
    }

    #[test]
    fn counts_match_d2() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(0xA1D);
        let mut subs = RegionsNd::new(2);
        let mut upds = RegionsNd::new(2);
        for _ in 0..200 {
            let r: Vec<Interval> = (0..2)
                .map(|_| {
                    let lo = rng.uniform(0.0, 100.0) as f32 as f64;
                    let len = rng.uniform(0.0, 10.0) as f32 as f64;
                    Interval::new(lo, (lo + len) as f32 as f64)
                })
                .collect();
            subs.push(&r);
        }
        for _ in 0..150 {
            let r: Vec<Interval> = (0..2)
                .map(|_| {
                    let lo = rng.uniform(0.0, 100.0) as f32 as f64;
                    let len = rng.uniform(0.0, 10.0) as f32 as f64;
                    Interval::new(lo, (lo + len) as f32 as f64)
                })
                .collect();
            upds.push(&r);
        }
        let mut want = 0u64;
        for i in 0..subs.len() {
            for j in 0..upds.len() {
                if subs.rects_intersect(i, &upds, j) {
                    want += 1;
                }
            }
        }
        let got = be.match_counts(&subs, &upds).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn prefix_sum_matches_cumsum() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(0xA1E);
        let xs: Vec<i32> = (0..10_000).map(|_| rng.range(-5, 6) as i32).collect();
        let got = be.prefix_sum(&xs).unwrap();
        let mut acc = 0;
        let want: Vec<i32> = xs
            .iter()
            .map(|&x| {
                acc += x;
                acc
            })
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn empty_inputs_count_zero() {
        let Some(be) = backend() else { return };
        let empty = Regions1D::default();
        assert_eq!(be.match_counts_1d(&empty, &empty).unwrap(), 0);
    }
}

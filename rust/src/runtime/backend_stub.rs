//! Stub XLA backend (built when the `xla` feature is **off**).
//!
//! Keeps the full [`XlaMatchBackend`] API surface compiling in
//! dependency-free builds; every entry point reports the backend as
//! unavailable. [`crate::runtime::artifacts_available`] returns `false`
//! in this configuration, so well-behaved callers (benches, examples,
//! the `ddm xla-match` subcommand) skip before ever reaching these.

use std::path::Path;

use crate::bail;
use crate::error::Result;
use crate::core::{Regions1D, RegionsNd};

pub use super::{quantize_f32, PAD};

/// DDM matching backed by compiled XLA executables (stubbed out).
pub struct XlaMatchBackend {
    _private: (),
}

const UNAVAILABLE: &str =
    "XLA backend unavailable: ddm was built without the `xla` feature";

impl XlaMatchBackend {
    pub fn load(_dir: &Path) -> Result<Self> {
        bail!("{UNAVAILABLE}")
    }

    /// Capacities (n, m) of the counts artifact for dimension `d`.
    pub fn counts_capacity(&self, _d: usize) -> Option<(usize, usize)> {
        None
    }

    /// Total intersection count via the tiled counts kernel.
    pub fn match_counts(&self, _subs: &RegionsNd, _upds: &RegionsNd) -> Result<u64> {
        bail!("{UNAVAILABLE}")
    }

    /// Enumerate intersecting pairs via the mask kernel.
    pub fn match_pairs(
        &self,
        _subs: &RegionsNd,
        _upds: &RegionsNd,
    ) -> Result<Vec<(u32, u32)>> {
        bail!("{UNAVAILABLE}")
    }

    /// Run the compiled Fig.-7 prefix-sum pipeline.
    pub fn prefix_sum(&self, _xs: &[i32]) -> Result<Vec<i32>> {
        bail!("{UNAVAILABLE}")
    }

    /// 1-D convenience wrappers (benches use these).
    pub fn match_counts_1d(&self, _subs: &Regions1D, _upds: &Regions1D) -> Result<u64> {
        bail!("{UNAVAILABLE}")
    }

    pub fn match_pairs_1d(
        &self,
        _subs: &Regions1D,
        _upds: &Regions1D,
    ) -> Result<Vec<(u32, u32)>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = XlaMatchBackend::load(Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("xla"));
    }
}

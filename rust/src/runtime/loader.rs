//! PJRT compilation of manifest artifacts (`xla` feature only).
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`), not
//! serialized protos: jax ≥ 0.5 emits 64-bit instruction ids that the
//! pinned xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::path::Path;

use crate::error::{Context, Result};

pub use super::manifest::{ArtifactKind, ArtifactMeta, Manifest};

/// A compiled artifact, ready to execute.
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    pub exe: xla::PjRtLoadedExecutable,
}

/// Compile every manifest entry on a PJRT CPU client.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts: Vec<LoadedArtifact>,
}

impl Runtime {
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut artifacts = Vec::with_capacity(manifest.entries.len());
        for meta in manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(&meta.path)
                .with_context(|| format!("parsing HLO text {}", meta.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", meta.name))?;
            artifacts.push(LoadedArtifact { meta, exe });
        }
        Ok(Runtime { client, artifacts })
    }

    pub fn find(&self, kind: ArtifactKind, d: usize) -> Option<&LoadedArtifact> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.meta.kind == kind && (kind == ArtifactKind::Scan || a.meta.d == d)
            })
            .max_by_key(|a| a.meta.n * a.meta.m.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_manifest_loads_and_compiles() {
        let dir = Path::new(crate::runtime::DEFAULT_ARTIFACT_DIR);
        if !crate::runtime::artifacts_available(dir) {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::load(dir).expect("runtime loads");
        assert!(rt.find(ArtifactKind::Counts, 1).is_some());
        assert!(rt.find(ArtifactKind::Mask, 1).is_some());
        assert!(rt.find(ArtifactKind::Scan, 0).is_some());
    }
}

//! Artifact manifest parsing (no PJRT dependency — usable whether or
//! not the `xla` feature is enabled).
//!
//! `artifacts/manifest.txt` lines look like:
//!
//! ```text
//! match_counts_2048x2048_d1 kind=counts file=match_counts_2048x2048_d1.hlo.txt sha256=747d... n=2048 m=2048 d=1 ts=256 tu=256
//! prefix_sum_65536 kind=scan file=prefix_sum_65536.hlo.txt sha256=9f21... n=65536 block=4096
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::error::{Context, Result};

/// What a compiled artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Dense [n, m] uint8 intersection mask.
    Mask,
    /// Per-subscription counts [n] + scalar total.
    Counts,
    /// Blocked prefix sum over [n] int32.
    Scan,
}

impl std::str::FromStr for ArtifactKind {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mask" => Ok(ArtifactKind::Mask),
            "counts" => Ok(ArtifactKind::Counts),
            "scan" => Ok(ArtifactKind::Scan),
            other => bail!("unknown artifact kind '{other}'"),
        }
    }
}

/// Parsed manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    pub path: PathBuf,
    pub sha256_prefix: String,
    /// `n`/`m`: compiled region capacities (or scan length in `n`).
    pub n: usize,
    pub m: usize,
    /// Dimensionality (mask/counts) — 0 for scan artifacts.
    pub d: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut entries = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_whitespace();
            let name = toks.next().context("missing artifact name")?.to_string();
            let kv: BTreeMap<&str, &str> = toks
                .filter_map(|t| t.split_once('='))
                .collect();
            let get = |k: &str| -> Result<&str> {
                kv.get(k)
                    .copied()
                    .with_context(|| format!("manifest line {}: missing {k}", ln + 1))
            };
            let kind: ArtifactKind = get("kind")?.parse()?;
            let n: usize = get("n")?.parse()?;
            let (m, d) = match kind {
                ArtifactKind::Scan => (0, 0),
                _ => (get("m")?.parse()?, get("d")?.parse()?),
            };
            entries.push(ArtifactMeta {
                name,
                kind,
                path: dir.join(get("file")?),
                sha256_prefix: get("sha256").unwrap_or("").to_string(),
                n,
                m,
                d,
            });
        }
        Ok(Manifest { entries })
    }

    /// Find the artifact of `kind` and dimensionality `d` with the
    /// largest capacity (the backend tiles bigger inputs over it).
    pub fn find(&self, kind: ArtifactKind, d: usize) -> Option<&ArtifactMeta> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && (kind == ArtifactKind::Scan || e.d == d))
            .max_by_key(|e| e.n * e.m.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
match_mask_1024x1024_d1 kind=mask file=a.hlo.txt sha256=abcd n=1024 m=1024 d=1 ts=256 tu=256
match_counts_2048x2048_d2 kind=counts file=b.hlo.txt sha256=ef01 n=2048 m=2048 d=2 ts=256 tu=256
prefix_sum_65536 kind=scan file=c.hlo.txt sha256=2345 n=65536 block=4096
";

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.entries[0].kind, ArtifactKind::Mask);
        assert_eq!(m.entries[0].n, 1024);
        assert_eq!(m.entries[1].d, 2);
        assert_eq!(m.entries[2].kind, ArtifactKind::Scan);
        assert_eq!(m.entries[2].n, 65536);
        assert!(m.entries[0].path.ends_with("a.hlo.txt"));
    }

    #[test]
    fn find_selects_matching_dimension() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.find(ArtifactKind::Mask, 1).is_some());
        assert!(m.find(ArtifactKind::Mask, 3).is_none());
        assert_eq!(m.find(ArtifactKind::Counts, 2).unwrap().n, 2048);
        assert!(m.find(ArtifactKind::Scan, 0).is_some());
    }

    #[test]
    fn bad_kind_is_error() {
        let bad = "x kind=frobnicate file=f n=1";
        assert!(Manifest::parse(bad, Path::new(".")).is_err());
    }
}

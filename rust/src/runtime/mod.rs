//! PJRT/XLA runtime: load and execute the AOT-compiled JAX+Pallas
//! matching kernels from `artifacts/*.hlo.txt`.
//!
//! This is the request-path end of the three-layer architecture:
//! Python lowers the L2 graphs once at build time (`make artifacts`);
//! the Rust coordinator compiles the HLO text with the PJRT CPU client
//! at startup and executes it directly — no Python anywhere near the
//! request path.
//!
//! The PJRT path needs the external `xla` crate, which offline builds
//! do not have; it is therefore gated behind the **`xla` cargo
//! feature**. Without the feature, [`backend`] is a stub whose entry
//! points report the backend as unavailable and
//! [`artifacts_available`] returns `false`, so every caller skips
//! politely. Manifest parsing ([`manifest`]) works in both builds.
//!
//! The backend also plugs into the engine API: see
//! `examples/xla_backend.rs`, which wraps [`XlaMatchBackend`] in a
//! [`crate::engine::Matcher`] so it can be driven — and benchmarked —
//! through the same trait as the native algorithms.

pub mod manifest;

#[cfg(feature = "xla")]
pub mod loader;

#[cfg(feature = "xla")]
pub mod backend;

#[cfg(not(feature = "xla"))]
#[path = "backend_stub.rs"]
pub mod backend;

pub use backend::XlaMatchBackend;
pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};

/// Default artifact directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Padding sentinel — must match `python/compile/kernels/overlap.py`.
pub const PAD: f32 = 1.0e30;

/// True when the crate was built with the `xla` feature.
pub fn xla_enabled() -> bool {
    cfg!(feature = "xla")
}

/// True if the XLA backend can actually run: the crate was built with
/// the `xla` feature **and** AOT artifacts are present. Tests, benches
/// and examples skip politely when this is false.
pub fn artifacts_available(dir: &std::path::Path) -> bool {
    xla_enabled() && dir.join("manifest.txt").exists()
}

/// Round region coordinates to f32 precision (in f64 storage).
///
/// The XLA kernels compute in f32; results agree with the native f64
/// matchers exactly on f32-representable inputs. Callers comparing
/// backends (tests, the `xla_backend` example, the A3 ablation) should
/// quantize first; production users with sub-f32-ulp coordinate
/// differences should scale their routing space instead.
pub fn quantize_f32(r: &crate::core::Regions1D) -> crate::core::Regions1D {
    crate::core::Regions1D {
        lo: r.lo.iter().map(|&x| x as f32 as f64).collect(),
        hi: r.hi.iter().map(|&x| x as f32 as f64).collect(),
    }
}

//! PJRT/XLA runtime: load and execute the AOT-compiled JAX+Pallas
//! matching kernels from `artifacts/*.hlo.txt`.
//!
//! This is the request-path end of the three-layer architecture:
//! Python lowers the L2 graphs once at build time (`make artifacts`);
//! the Rust coordinator compiles the HLO text with the PJRT CPU client
//! at startup and executes it directly — no Python anywhere near the
//! request path.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`), not
//! serialized protos: jax ≥ 0.5 emits 64-bit instruction ids that the
//! pinned xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod backend;
pub mod loader;

pub use backend::XlaMatchBackend;
pub use loader::{ArtifactKind, ArtifactMeta, Manifest};

/// Default artifact directory (relative to the repo root / CWD).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True if AOT artifacts are present (tests/benches skip politely
/// when `make artifacts` has not run).
pub fn artifacts_available(dir: &std::path::Path) -> bool {
    dir.join("manifest.txt").exists()
}

//! Bounded MPSC ingestion front-end with admission control.
//!
//! Producers (network IO threads, in-process writers) push staged
//! region ops through a cloneable [`IngestSender`] without ever
//! touching the session; the session's single owner drains the queue
//! at its next flush/commit (see
//! [`DdmSession::drain_ingest`](super::DdmSession::drain_ingest)).
//! The queue is **bounded**: once `capacity` ops are in flight,
//! [`IngestSender::try_upsert`] / [`try_remove`](IngestSender::try_remove)
//! reject with a typed [`Busy`] instead of blocking or buffering
//! without limit — the net worker turns that into a `Busy` wire reply
//! and the live depth into the `ingest_backlog` coordinator gauge.
//!
//! Each op carries its enqueue timestamp; drains fold the queue dwell
//! into a [`backlog_wait`](crate::obs::Phase::BacklogWait) span, so
//! traced commits show how long the batch sat in the backlog before
//! the pipeline picked it up.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;

use super::Side;
use crate::core::interval::Interval;

/// One staged region op in flight through the queue: the same
/// `key → Some(rect) | None` shape the session coalesces, plus the
/// enqueue timestamp for backlog-dwell accounting.
#[derive(Debug, Clone)]
pub struct StagedOp {
    pub side: Side,
    pub key: u32,
    /// `Some(rect)` upsert / `None` remove.
    pub op: Option<Vec<Interval>>,
    /// [`crate::obs::clock::now_ns`] at enqueue.
    pub enqueued_ns: u64,
}

/// Typed admission-control rejection: the staged-op backlog is full.
/// Carries the observed depth and the configured limit so callers can
/// surface both (the wire protocol's `Busy` reply is exactly this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Busy {
    /// Ops in flight when the send was rejected.
    pub pending: u64,
    /// The queue's capacity.
    pub limit: u64,
}

/// Depth gauge shared by every sender and the receiver. The counter is
/// reserved *before* the channel send, so concurrent producers can
/// never overshoot the capacity.
#[derive(Debug)]
struct Gauge {
    depth: AtomicUsize,
    cap: usize,
}

/// The producer half: cloneable, send-only, never blocks.
#[derive(Clone)]
pub struct IngestSender {
    tx: SyncSender<StagedOp>,
    gauge: Arc<Gauge>,
}

impl IngestSender {
    /// Enqueue an insert-or-replace of region `key` on `side`.
    pub fn try_upsert(&self, side: Side, key: u32, rect: &[Interval]) -> Result<(), Busy> {
        self.try_send(side, key, Some(rect.to_vec()))
    }

    /// Enqueue a removal of region `key` on `side`.
    pub fn try_remove(&self, side: Side, key: u32) -> Result<(), Busy> {
        self.try_send(side, key, None)
    }

    fn try_send(&self, side: Side, key: u32, op: Option<Vec<Interval>>) -> Result<(), Busy> {
        let busy = |pending: usize| Busy {
            pending: pending as u64,
            limit: self.gauge.cap as u64,
        };
        // Reserve a slot first: the add-then-check keeps racing
        // producers from overshooting the cap.
        let prev = self.gauge.depth.fetch_add(1, Ordering::AcqRel);
        if prev >= self.gauge.cap {
            self.gauge.depth.fetch_sub(1, Ordering::AcqRel);
            return Err(busy(prev));
        }
        let staged = StagedOp {
            side,
            key,
            op,
            enqueued_ns: crate::obs::clock::now_ns(),
        };
        match self.tx.try_send(staged) {
            Ok(()) => Ok(()),
            // Full can't normally happen (the gauge reserves within the
            // channel bound); Disconnected means the session side is
            // gone — report it as backpressure rather than panicking.
            Err(_) => {
                self.gauge.depth.fetch_sub(1, Ordering::AcqRel);
                Err(busy(self.gauge.cap))
            }
        }
    }

    /// Ops currently in flight (enqueued, not yet drained).
    pub fn depth(&self) -> usize {
        self.gauge.depth.load(Ordering::Acquire)
    }

    /// The bound the queue admits up to.
    pub fn capacity(&self) -> usize {
        self.gauge.cap
    }
}

/// The consumer half, owned next to the session.
pub struct IngestReceiver {
    rx: Receiver<StagedOp>,
    gauge: Arc<Gauge>,
}

impl IngestReceiver {
    /// Ops currently in flight (enqueued, not yet drained).
    pub fn depth(&self) -> usize {
        self.gauge.depth.load(Ordering::Acquire)
    }

    /// The bound the queue admits up to.
    pub fn capacity(&self) -> usize {
        self.gauge.cap
    }

    /// Drain everything queued right now into `apply` (enqueue order).
    /// Returns the drained count and the oldest enqueue timestamp
    /// (`u64::MAX` when nothing was queued) — the session turns the
    /// pair into one `backlog_wait` span.
    pub fn drain(&self, mut apply: impl FnMut(StagedOp)) -> (usize, u64) {
        let mut n = 0usize;
        let mut oldest = u64::MAX;
        while let Ok(op) = self.rx.try_recv() {
            self.gauge.depth.fetch_sub(1, Ordering::AcqRel);
            oldest = oldest.min(op.enqueued_ns);
            n += 1;
            apply(op);
        }
        (n, oldest)
    }
}

/// Build a bounded MPSC staged-op queue admitting up to `cap` ops
/// (`cap` is clamped to ≥ 1).
pub fn ingest_queue(cap: usize) -> (IngestSender, IngestReceiver) {
    let cap = cap.max(1);
    let gauge = Arc::new(Gauge {
        depth: AtomicUsize::new(0),
        cap,
    });
    let (tx, rx) = sync_channel(cap);
    (
        IngestSender {
            tx,
            gauge: Arc::clone(&gauge),
        },
        IngestReceiver { rx, gauge },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iv() -> Interval {
        Interval::new(0.0, 1.0)
    }

    #[test]
    fn admits_up_to_capacity_then_rejects_typed_busy() {
        let (tx, rx) = ingest_queue(3);
        assert_eq!(tx.capacity(), 3);
        for k in 0..3u32 {
            tx.try_upsert(Side::Subscription, k, &[iv()]).unwrap();
        }
        assert_eq!(tx.depth(), 3);
        let err = tx.try_remove(Side::Update, 9).unwrap_err();
        assert_eq!(err, Busy { pending: 3, limit: 3 });
        // Draining frees the slots again.
        let mut keys = Vec::new();
        let (n, oldest) = rx.drain(|op| keys.push(op.key));
        assert_eq!(n, 3);
        assert!(oldest != u64::MAX);
        assert_eq!(keys, vec![0, 1, 2]);
        assert_eq!(tx.depth(), 0);
        tx.try_upsert(Side::Update, 4, &[iv()]).unwrap();
        assert_eq!(rx.depth(), 1);
    }

    #[test]
    fn drain_on_empty_queue_is_a_cheap_no_op() {
        let (_tx, rx) = ingest_queue(4);
        let (n, oldest) = rx.drain(|_| panic!("nothing to drain"));
        assert_eq!(n, 0);
        assert_eq!(oldest, u64::MAX);
    }

    #[test]
    fn concurrent_producers_never_overshoot_the_bound() {
        let (tx, rx) = ingest_queue(64);
        let accepted: usize = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4u32)
                .map(|t| {
                    let tx = tx.clone();
                    scope.spawn(move || {
                        let mut ok = 0usize;
                        for k in 0..100u32 {
                            if tx.try_upsert(Side::Subscription, t * 1000 + k, &[iv()]).is_ok() {
                                ok += 1;
                            }
                        }
                        ok
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        assert!(accepted <= 64, "admitted {accepted} ops past the bound");
        let (n, _) = rx.drain(|_| ());
        assert_eq!(n, accepted, "every admitted op is drainable");
        assert_eq!(rx.depth(), 0);
    }
}

//! Epoch-based incremental matching sessions: batched region churn
//! in, intersection *diffs* out.
//!
//! [`DdmSession`] is the dynamic counterpart of the static
//! [`DdmEngine`](crate::engine::DdmEngine) matching entry points, and
//! the system-scale form of the paper's §3 dynamic interval
//! management. A session owns the full N-D two-tree state — one keyed
//! interval tree ([`TreeIndex`](crate::algos::dynamic::TreeIndex)) per
//! dimension per side, not a dimension-0 index plus dense-array
//! filtering — plus a retained pair set backed by the pluggable
//! [`sets`](crate::sets) layer ([`DynSet`]).
//!
//! Callers stage region churn
//! ([`upsert_subscription`](DdmSession::upsert_subscription),
//! [`upsert_update`](DdmSession::upsert_update),
//! [`remove_subscription`](DdmSession::remove_subscription), …) and
//! [`commit`](DdmSession::commit) an **epoch**. Commit applies the
//! coalesced batch to the `2d` per-dimension trees (in parallel on the
//! engine's [`exec`](crate::exec) pool once the batch is large
//! enough), recomputes the overlap sets of the *touched* regions only
//! (output-sensitively, via opposite-tree queries), updates the
//! retained pair set, and returns a [`MatchDiff`] — exactly the pairs
//! that appeared and disappeared since the previous epoch. Nothing is
//! ever re-matched from scratch and nothing already known is
//! re-reported.
//!
//! Per-epoch cost with `t` touched regions: `O(t·d·lg n)` tree writes,
//! `O(Σ_t K)` opposite-tree queries and `O(|diff|)` retained-set
//! updates — against the `O(full re-match + full re-report)` of the
//! rebuild path. `benches/abl_session.rs` measures the crossover over
//! churn rates; at low churn (≤10% of regions touched per epoch) the
//! diff path wins by a wide margin.
//!
//! Sessions are configured through the engine builder
//! ([`session_set_impl`](crate::engine::EngineBuilder::session_set_impl),
//! [`batch_threshold`](crate::engine::EngineBuilder::batch_threshold),
//! [`parallel_cutoff`](crate::engine::EngineBuilder::parallel_cutoff))
//! and created by [`DdmEngine::session`](crate::engine::DdmEngine::session).
//!
//! Since the MVCC refactor the session is split across three files:
//! this one owns the mutable write side (staging, apply, commit),
//! [`snapshot`] owns the immutable read side — a refcounted
//! [`EpochSnapshot`] republished by RCU-style pointer swap at every
//! flush/commit, so readers are wait-free and never observe a commit
//! in progress — and [`ingest`] adds a bounded MPSC staging front-end
//! with typed [`Busy`] backpressure.
//! [`commit_pipelined`](DdmSession::commit_pipelined) overlaps the
//! *next* batch's phase-A tree writes with the current epoch's diff
//! assembly and snapshot swap.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;

pub use crate::algos::dynamic::Side;

pub mod ingest;
pub mod snapshot;

pub use ingest::{ingest_queue, Busy, IngestReceiver, IngestSender, StagedOp};
pub use snapshot::EpochSnapshot;

use crate::algos::dynamic::TreeIndex;
use crate::core::interval::Interval;
use crate::core::scratch::MatchScratch;
use crate::core::sink::{pack_pair, unpack_pair, PairVec};
use crate::core::{Regions1D, RegionsNd};
use crate::exec::ThreadPool;
use crate::sets::{DynSet, SetImpl};

/// Session tuning knobs (set through the
/// [`EngineBuilder`](crate::engine::EngineBuilder)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionParams {
    /// Backing store of the retained pair set (one [`DynSet`] of
    /// opposite-side keys per region, both directions). Default
    /// [`SetImpl::Hash`]: the Θ(universe)-per-set implementations
    /// (`Bit`, `Sparse`) only pay off when the key space is small
    /// relative to the average overlap degree.
    pub set_impl: SetImpl,
    /// Auto-apply the staged batch to the indexes once this many
    /// distinct regions are pending (ops coalesce last-write-wins per
    /// key at stage time, so this bounds *touched regions*, and with
    /// it commit latency, under heavy churn; `0` = apply only at
    /// [`DdmSession::commit`]). Applying early never changes the
    /// committed diff — intra-epoch appear/disappear pairs cancel.
    pub batch_threshold: usize,
    /// Minimum touched regions per batch before the apply and
    /// recompute phases run on the worker pool instead of inline.
    pub parallel_cutoff: usize,
    /// Reuse the session's [`MatchScratch`] (per-region query buffers
    /// and diff scratch) across epochs, so steady-state commits stop
    /// allocating (default). `false` drops the buffers after every
    /// apply — the cold baseline `benches/abl_session.rs` measures
    /// against.
    pub reuse_scratch: bool,
    /// Capture commit phase spans ([`crate::obs`]): stage-apply, tree
    /// writes, recompute, diff-merge, plus a whole-commit envelope.
    /// Off by default — the disabled path is a branch per phase. Read
    /// the timeline with [`DdmSession::drain_trace`].
    pub trace: bool,
    /// Admission bound of the async ingestion front-end: how many
    /// staged ops an [`ingest_queue`] built for this session admits
    /// before senders get a typed [`Busy`] (the net worker sizes its
    /// backlog from this and surfaces rejections as `Busy` wire
    /// replies).
    pub ingest_backlog: usize,
}

/// Default [`SessionParams::ingest_backlog`] bound.
pub const DEFAULT_INGEST_BACKLOG: usize = 1 << 16;

impl Default for SessionParams {
    fn default() -> Self {
        Self {
            set_impl: SetImpl::Hash,
            batch_threshold: 4096,
            parallel_cutoff: 64,
            reuse_scratch: true,
            trace: false,
            ingest_backlog: DEFAULT_INGEST_BACKLOG,
        }
    }
}

/// The intersection delta of one committed epoch: every (subscription
/// key, update key) pair that appeared or disappeared relative to the
/// previous epoch, each list sorted and duplicate-free.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MatchDiff {
    /// Epoch number this diff brought the session to (first commit ⇒ 1).
    pub epoch: u64,
    /// Pairs that started intersecting.
    pub added: PairVec,
    /// Pairs that stopped intersecting.
    pub removed: PairVec,
}

impl MatchDiff {
    pub fn is_empty(&self) -> bool {
        self.added.is_empty() && self.removed.is_empty()
    }

    /// Total pair churn (|added| + |removed|).
    pub fn churn(&self) -> usize {
        self.added.len() + self.removed.len()
    }
}

/// An epoch-based incremental matching session. See the
/// [module docs](self) for the model and cost story.
///
/// Keys are caller-chosen `u32`s per side (the HLA service uses region
/// handle ids). Upserting an existing key replaces its rectangle;
/// removing an absent key is a no-op.
pub struct DdmSession {
    d: usize,
    pool: Arc<ThreadPool>,
    nthreads: usize,
    params: SessionParams,
    /// One keyed interval tree per dimension, subscription side.
    sub_dims: Vec<TreeIndex>,
    /// One keyed interval tree per dimension, update side.
    upd_dims: Vec<TreeIndex>,
    /// Retained pair set: subscription key → intersecting update keys.
    sub_pairs: HashMap<u32, DynSet>,
    /// Reverse direction: update key → intersecting subscription keys
    /// (keeps update-side removal output-sensitive).
    upd_pairs: HashMap<u32, DynSet>,
    n_pairs: usize,
    /// Universe hint for new [`DynSet`]s (max key seen + 1).
    key_hint: usize,
    /// Staged ops, coalesced last-write-wins at stage time:
    /// key → `Some(rect)` upsert / `None` remove, per side.
    pending_subs: BTreeMap<u32, Option<Vec<Interval>>>,
    pending_upds: BTreeMap<u32, Option<Vec<Interval>>>,
    /// Next-epoch ops whose phase-A tree writes already ran during a
    /// [`commit_pipelined`](Self::commit_pipelined) overlap; the next
    /// apply merges them in (fresh staged ops win per key) and runs
    /// recompute + diff for them without re-writing their tree
    /// entries.
    prewritten_subs: BTreeMap<u32, Option<Vec<Interval>>>,
    prewritten_upds: BTreeMap<u32, Option<Vec<Interval>>>,
    /// Pair churn accumulated by intra-epoch applies, packed; an
    /// appear/disappear of the same pair within one epoch cancels.
    acc_added: HashSet<u64>,
    acc_removed: HashSet<u64>,
    epoch: u64,
    /// Reusable per-epoch buffers (recompute query results and diff
    /// scratch) — the dominant per-commit allocations on the steady
    /// state. See [`SessionParams::reuse_scratch`].
    scratch: MatchScratch,
    /// Commit phase-span capture ([`SessionParams::trace`]; disabled
    /// tracers cost one branch per phase boundary).
    tracer: crate::obs::Tracer,
    /// The published read-side view, RCU-swapped at every publish
    /// point (flush / commit). Readers clone it and keep reading the
    /// old payload untouched after later swaps.
    snap: EpochSnapshot,
    /// Applied state has changed since the last snapshot publish
    /// (set by `apply_pending`, cleared by `publish_snapshot`) — lets
    /// flush republish after intra-staging auto-applies without
    /// rebuilding on every batch.
    dirty_since_publish: bool,
    /// Crash-consistency: staged ops are appended here at stage time
    /// and flushed to disk *before* a commit publishes; every commit
    /// closes with a durable marker
    /// ([`crate::engine::EngineBuilder::durability`]). `None` (the
    /// default) costs one branch per stage/commit.
    wal: Option<crate::durable::SessionWal>,
}

impl DdmSession {
    /// A fresh `d`-dimensional session running batch applies on
    /// `nthreads` workers of `pool`. Usually constructed via
    /// [`DdmEngine::session`](crate::engine::DdmEngine::session).
    pub fn new(d: usize, pool: Arc<ThreadPool>, nthreads: usize, params: SessionParams) -> Self {
        assert!(d >= 1, "sessions need at least one dimension");
        assert!(nthreads >= 1, "sessions need at least one worker");
        Self {
            d,
            pool,
            nthreads,
            params,
            sub_dims: (0..d).map(|_| TreeIndex::new()).collect(),
            upd_dims: (0..d).map(|_| TreeIndex::new()).collect(),
            sub_pairs: HashMap::new(),
            upd_pairs: HashMap::new(),
            n_pairs: 0,
            key_hint: 64,
            pending_subs: BTreeMap::new(),
            pending_upds: BTreeMap::new(),
            prewritten_subs: BTreeMap::new(),
            prewritten_upds: BTreeMap::new(),
            acc_added: HashSet::new(),
            acc_removed: HashSet::new(),
            epoch: 0,
            scratch: MatchScratch::new(),
            tracer: crate::obs::Tracer::new(params.trace),
            snap: EpochSnapshot::default(),
            dirty_since_publish: false,
            wal: None,
        }
    }

    /// Attach a write-ahead log: every op staged from here on is
    /// journaled, and every commit appends a durable marker. Called by
    /// the engine's construction/recovery paths; attaching mid-life is
    /// only sound when the log's history matches the session's state
    /// (fresh log on a fresh session, or a recovered log on the
    /// session recovery just rebuilt).
    pub(crate) fn attach_wal(&mut self, wal: crate::durable::SessionWal) {
        self.wal = Some(wal);
    }

    /// Write-ahead log counters, if durability is attached.
    pub fn wal_stats(&self) -> Option<crate::durable::WalStats> {
        self.wal.as_ref().map(crate::durable::SessionWal::stats)
    }

    /// The error that degraded the log, if any.
    pub fn wal_error(&self) -> Option<String> {
        self.wal
            .as_ref()
            .and_then(|w| w.last_error().map(str::to_string))
    }

    /// Force the epoch counter and republish the snapshot under it —
    /// recovery's final step, pinning a replayed session to the exact
    /// durable epoch its history ended at.
    pub(crate) fn force_epoch(&mut self, epoch: u64) {
        self.epoch = epoch;
        let (ns, nu) = (self.n_subscriptions(), self.n_updates());
        self.publish_snapshot(ns, nu);
    }

    /// Install a checkpoint of the current committed state right now
    /// (resume does this so the recovered-from log tail is truncated).
    pub(crate) fn checkpoint_now(&mut self) {
        if let Some(wal) = self.wal.as_mut() {
            wal.checkpoint(&self.snap);
        }
    }

    /// The tuning knobs this session was built with.
    pub fn params(&self) -> SessionParams {
        self.params
    }

    /// Whether this session is capturing commit phase spans.
    pub fn trace_enabled(&self) -> bool {
        self.tracer.is_enabled()
    }

    /// Take the phase spans recorded since the last drain (empty when
    /// built without [`SessionParams::trace`]). Master-lane spans:
    /// the commit envelope and each phase, in record order.
    pub fn drain_trace(&mut self) -> Vec<crate::obs::SpanRecord> {
        self.tracer.drain()
    }

    /// Spans lost to full trace buffers since construction.
    pub fn trace_dropped(&self) -> u64 {
        self.tracer.dropped()
    }

    /// Timestamp for a caller-recorded span (recovery's
    /// [`recover_scan`](crate::obs::Phase::RecoverScan) envelope).
    pub(crate) fn trace_start(&self) -> u64 {
        self.tracer.start()
    }

    /// Record a caller-timed master-lane span on this session's
    /// tracer.
    pub(crate) fn trace_span(&mut self, phase: crate::obs::Phase, t0: u64, items: u64) {
        self.tracer.span(phase, t0, items);
    }

    /// Capacity snapshot of the session's reusable scratch — equal
    /// snapshots around a warm commit mean the epoch allocated nothing
    /// from the pooled buffers.
    pub fn scratch_stats(&self) -> crate::core::ScratchStats {
        self.scratch.stats()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of committed epochs.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Staged (coalesced) region ops not yet applied to the indexes.
    pub fn pending_ops(&self) -> usize {
        self.pending_subs.len() + self.pending_upds.len()
    }

    /// Live subscription regions (applied state).
    pub fn n_subscriptions(&self) -> usize {
        self.sub_dims[0].len()
    }

    /// Live update regions (applied state).
    pub fn n_updates(&self) -> usize {
        self.upd_dims[0].len()
    }

    /// Currently intersecting pairs (applied state).
    pub fn n_pairs(&self) -> usize {
        self.n_pairs
    }

    /// Live regions on one side (applied state), O(1) — side-keyed
    /// spelling of [`n_subscriptions`](Self::n_subscriptions) /
    /// [`n_updates`](Self::n_updates) for callers that hold a
    /// [`Side`]: the per-shard load snapshot
    /// ([`crate::shard::ShardedSession::shard_stats`], which feeds the
    /// imbalance gauge) is built from it.
    pub fn region_count(&self, side: Side) -> usize {
        match side {
            Side::Subscription => self.n_subscriptions(),
            Side::Update => self.n_updates(),
        }
    }

    /// Currently retained intersecting pairs (applied state) — the
    /// introspection alias of [`n_pairs`](Self::n_pairs), O(1).
    pub fn retained_pair_count(&self) -> usize {
        self.n_pairs()
    }

    // ---- staging -----------------------------------------------------------

    /// Stage an insert-or-replace of subscription region `key`.
    pub fn upsert_subscription(&mut self, key: u32, rect: &[Interval]) {
        self.stage(Side::Subscription, key, Some(rect.to_vec()));
    }

    /// Stage an insert-or-replace of update region `key`.
    pub fn upsert_update(&mut self, key: u32, rect: &[Interval]) {
        self.stage(Side::Update, key, Some(rect.to_vec()));
    }

    /// Stage removal of subscription region `key` (no-op if absent).
    pub fn remove_subscription(&mut self, key: u32) {
        self.stage(Side::Subscription, key, None);
    }

    /// Stage removal of update region `key` (no-op if absent).
    pub fn remove_update(&mut self, key: u32) {
        self.stage(Side::Update, key, None);
    }

    /// Stage a whole 1-D workload keyed by dense index (bulk ingest for
    /// benches/replays).
    pub fn load_dense_1d(&mut self, subs: &Regions1D, upds: &Regions1D) {
        assert_eq!(self.d, 1, "load_dense_1d on a {}-d session", self.d);
        for i in 0..subs.len() {
            self.upsert_subscription(i as u32, &[subs.get(i)]);
        }
        for j in 0..upds.len() {
            self.upsert_update(j as u32, &[upds.get(j)]);
        }
    }

    /// Stage a whole d-dimensional workload keyed by dense index.
    pub fn load_dense(&mut self, subs: &RegionsNd, upds: &RegionsNd) {
        assert_eq!(subs.d(), self.d, "subscription dimension mismatch");
        assert_eq!(upds.d(), self.d, "update dimension mismatch");
        for i in 0..subs.len() {
            self.upsert_subscription(i as u32, &subs.get(i));
        }
        for j in 0..upds.len() {
            self.upsert_update(j as u32, &upds.get(j));
        }
    }

    /// Stage one op, coalescing last-write-wins per (side, key) —
    /// superseded rectangles are dropped at stage time, never stored.
    fn stage(&mut self, side: Side, key: u32, op: Option<Vec<Interval>>) {
        if let Some(rect) = &op {
            assert_eq!(rect.len(), self.d, "rect dimension != session dimension {}", self.d);
            self.key_hint = self.key_hint.max(key as usize + 1);
        }
        if let Some(wal) = self.wal.as_mut() {
            wal.log_op(side == Side::Subscription, key, op.as_deref());
        }
        match side {
            Side::Subscription => self.pending_subs.insert(key, op),
            Side::Update => self.pending_upds.insert(key, op),
        };
        if self.params.batch_threshold > 0 && self.pending_ops() >= self.params.batch_threshold {
            self.apply_pending();
        }
    }

    // ---- committing --------------------------------------------------------

    /// Apply all staged ops to the indexes **without closing the
    /// epoch**: reads ([`pairs`](Self::pairs),
    /// [`subscriptions_of`](Self::subscriptions_of), …) see current
    /// state, while the accumulated churn stays queued so the next
    /// [`commit`](Self::commit) still reports the full diff since the
    /// last epoch. Publishes a fresh [`EpochSnapshot`] when anything
    /// was applied since the last publish; a flush with nothing staged
    /// (and nothing auto-applied earlier) is a pure no-op — no apply,
    /// no swap, no side effect a reader could observe.
    pub fn flush(&mut self) {
        self.apply_pending();
        if self.dirty_since_publish {
            let (ns, nu) = (self.n_subscriptions(), self.n_updates());
            self.publish_snapshot(ns, nu);
        }
    }

    /// The published read-side view: a wait-free, refcounted snapshot
    /// of the applied state as of the last publish point
    /// ([`flush`](Self::flush) / [`commit`](Self::commit)). Cloning is
    /// an `Arc` bump; the returned snapshot's answers never change, no
    /// matter what the session does afterwards — readers on other
    /// threads are never blocked by (and never block) a commit.
    pub fn snapshot(&self) -> EpochSnapshot {
        self.snap.clone()
    }

    /// Drain a bounded [`ingest_queue`] into the staged batch (the
    /// MPSC front-end's consumer side). Records one
    /// [`backlog_wait`](crate::obs::Phase::BacklogWait) span covering
    /// the oldest drained op's queue dwell. Returns the drained count.
    pub fn drain_ingest(&mut self, rx: &IngestReceiver) -> usize {
        let (drained, oldest) = rx.drain(|op| {
            self.stage(op.side, op.key, op.op);
        });
        if drained > 0 && self.tracer.is_enabled() {
            let now = crate::obs::clock::now_ns();
            self.tracer.span_at(
                crate::obs::Phase::BacklogWait,
                crate::obs::trace::MASTER_WORKER,
                oldest.min(now),
                now,
                drained as u64,
            );
        }
        drained
    }

    /// Apply all staged ops and close the epoch, returning the
    /// intersection delta relative to the previous epoch.
    pub fn commit(&mut self) -> MatchDiff {
        self.commit_inner(BTreeMap::new(), BTreeMap::new())
    }

    /// [`commit`](Self::commit), pipelined with the **next** epoch's
    /// batch: while this epoch's diff is assembled and its snapshot
    /// swapped in (master lane), a second thread runs the phase-A tree
    /// writes for `next_subs`/`next_upds` — already-coalesced ops
    /// (`key → Some(rect)` upsert / `None` remove), e.g. drained from
    /// an [`ingest_queue`]. The prewritten ops then ride along with
    /// the next apply (staged ops arriving later win per key), which
    /// skips their tree writes and runs only recompute + diff.
    ///
    /// The returned diff and the published snapshot are exactly those
    /// of a plain [`commit`](Self::commit) — the overlap only moves
    /// *next*-epoch tree work off the critical path. Until that next
    /// apply, [`subscription_rect`](Self::subscription_rect) /
    /// [`update_rect`](Self::update_rect) (which read the trees) may
    /// already see the prewritten rectangles.
    pub fn commit_pipelined(
        &mut self,
        next_subs: BTreeMap<u32, Option<Vec<Interval>>>,
        next_upds: BTreeMap<u32, Option<Vec<Interval>>>,
    ) -> MatchDiff {
        self.commit_inner(next_subs, next_upds)
    }

    fn commit_inner(
        &mut self,
        next_subs: BTreeMap<u32, Option<Vec<Interval>>>,
        next_upds: BTreeMap<u32, Option<Vec<Interval>>>,
    ) -> MatchDiff {
        let t_commit = self.tracer.start();
        // Write-ahead point: the epoch's op records must be on disk
        // before anything of this commit becomes observable.
        if let Some(wal) = self.wal.as_mut() {
            wal.flush_ops(&mut self.tracer);
        }
        self.apply_pending();
        self.epoch += 1;
        let (ns, nu) = (self.n_subscriptions(), self.n_updates());
        let (added, removed) = if next_subs.is_empty() && next_upds.is_empty() {
            self.drain_and_publish(ns, nu)
        } else {
            // Pipelined overlap: the next batch's tree writes touch
            // only `sub_dims`/`upd_dims` (taken out below), while diff
            // assembly + snapshot build touch only the pair sets and
            // accumulators — disjoint state, so the two run
            // concurrently without any locking.
            let mut sub_trees = std::mem::take(&mut self.sub_dims);
            let mut upd_trees = std::mem::take(&mut self.upd_dims);
            let (drained, t0, t1, wrote) = std::thread::scope(|scope| {
                let writer = scope.spawn(|| {
                    let t0 = crate::obs::clock::now_ns();
                    for (k, tree) in sub_trees.iter_mut().enumerate() {
                        apply_dim(tree, k, &next_subs);
                    }
                    for (k, tree) in upd_trees.iter_mut().enumerate() {
                        apply_dim(tree, k, &next_upds);
                    }
                    (t0, crate::obs::clock::now_ns())
                });
                let drained = self.drain_and_publish(ns, nu);
                let (t0, t1) = writer.join().expect("next-batch tree writer panicked");
                let wrote = (next_subs.len() + next_upds.len()) as u64;
                (drained, t0, t1, wrote)
            });
            self.sub_dims = sub_trees;
            self.upd_dims = upd_trees;
            // The overlapped writes get their own (worker 0) lane so a
            // trace shows them tiling *under* this commit's envelope.
            self.tracer
                .span_at(crate::obs::Phase::TreeWrite, 0, t0, t1, wrote);
            self.prewritten_subs = next_subs;
            self.prewritten_upds = next_upds;
            drained
        };
        if let Some(wal) = self.wal.as_mut() {
            // The marker makes the epoch durable; after it, journal
            // the pipelined next batch (its records belong to the
            // *next* epoch, so they must follow this marker) — they
            // stay buffered until the next commit's flush.
            wal.on_commit(&self.snap, &mut self.tracer);
            for (key, op) in &self.prewritten_subs {
                wal.log_op(true, *key, op.as_deref());
            }
            for (key, op) in &self.prewritten_upds {
                wal.log_op(false, *key, op.as_deref());
            }
        }
        let churn = (added.len() + removed.len()) as u64;
        self.tracer.span(crate::obs::Phase::Commit, t_commit, churn);
        MatchDiff {
            epoch: self.epoch,
            added,
            removed,
        }
    }

    /// Drain the epoch's churn accumulator into sorted added/removed
    /// lists and publish the post-commit snapshot. Runs on the master
    /// lane; in a pipelined commit it overlaps the next batch's tree
    /// writes.
    fn drain_and_publish(&mut self, n_subs: usize, n_upds: usize) -> (PairVec, PairVec) {
        // The accumulator drain + sort is diff assembly — charge it to
        // the same phase as apply_pending's phase-C diff work, so the
        // phase totals tile the whole commit envelope.
        let t_drain = self.tracer.start();
        let mut added: PairVec = self.acc_added.drain().map(unpack_pair).collect();
        let mut removed: PairVec = self.acc_removed.drain().map(unpack_pair).collect();
        added.sort_unstable();
        removed.sort_unstable();
        let churn = (added.len() + removed.len()) as u64;
        self.tracer
            .span(crate::obs::Phase::DiffMerge, t_drain, churn);
        self.publish_snapshot(n_subs, n_upds);
        (added, removed)
    }

    /// Rebuild the read-side view from the retained pair set and
    /// RCU-swap it in. `snapshot_swap` covers the rebuild + swap;
    /// `reader_pin` reports how many reader handles still pin the
    /// *previous* epoch's payload (they keep it alive until dropped).
    fn publish_snapshot(&mut self, n_subs: usize, n_upds: usize) {
        let t_swap = self.tracer.start();
        let mut packed: Vec<u64> = Vec::with_capacity(self.n_pairs);
        for (&s, set) in &self.sub_pairs {
            set.for_each(&mut |u| packed.push(pack_pair(s, u)));
        }
        packed.sort_unstable();
        let next = EpochSnapshot::from_packed(self.epoch, packed, n_subs, n_upds);
        let pinned = (self.snap.readers() - 1) as u64;
        self.snap = next;
        self.dirty_since_publish = false;
        self.tracer
            .span(crate::obs::Phase::SnapshotSwap, t_swap, self.n_pairs as u64);
        let t_pin = self.tracer.start();
        self.tracer
            .span(crate::obs::Phase::ReaderPin, t_pin, pinned);
    }

    /// Apply the staged (already coalesced) batch: write the trees,
    /// recompute the touched regions' overlap sets, fold the churn
    /// into the epoch accumulator.
    fn apply_pending(&mut self) {
        if self.pending_subs.is_empty()
            && self.pending_upds.is_empty()
            && self.prewritten_subs.is_empty()
            && self.prewritten_upds.is_empty()
        {
            return;
        }
        // Already coalesced at stage time: key → `Some(rect)` upsert /
        // `None` remove, per side. Ops prewritten by a pipelined
        // commit merge in (fresh staged ops win per key); their tree
        // entries are already current, so phase A below only writes
        // the fresh keys.
        let t_stage = self.tracer.start();
        let fresh_subs = std::mem::take(&mut self.pending_subs);
        let fresh_upds = std::mem::take(&mut self.pending_upds);
        let (sub_ops, sub_fresh) = merge_batch(std::mem::take(&mut self.prewritten_subs), fresh_subs);
        let (upd_ops, upd_fresh) = merge_batch(std::mem::take(&mut self.prewritten_upds), fresh_upds);
        if let Some(wal) = self.wal.as_mut() {
            // Shadow the committed region tables for checkpoints: the
            // trees may already hold next-epoch prewrites by the time
            // a checkpoint is cut, this merged batch is exactly what
            // the epoch commits.
            wal.apply_committed(&sub_ops, &upd_ops);
        }
        let touched_count = sub_ops.len() + upd_ops.len();
        let par = self.nthreads > 1 && touched_count >= self.params.parallel_cutoff;
        self.tracer
            .span(crate::obs::Phase::StageApply, t_stage, touched_count as u64);
        let t_tree = self.tracer.start();

        // Phase A: write the 2d per-dimension trees (each tree is an
        // independent job; parallel over trees for big batches — the
        // trees are *moved* to their workers, no lock hand-off).
        if par && self.d * 2 > 1 {
            let sub_trees = std::mem::take(&mut self.sub_dims);
            let upd_trees = std::mem::take(&mut self.upd_dims);
            let mut jobs: Vec<(Side, usize, TreeIndex)> = Vec::with_capacity(self.d * 2);
            for (k, t) in sub_trees.into_iter().enumerate() {
                jobs.push((Side::Subscription, k, t));
            }
            for (k, t) in upd_trees.into_iter().enumerate() {
                jobs.push((Side::Update, k, t));
            }
            let workers = self.nthreads.min(jobs.len());
            let (sub_ops_ref, upd_ops_ref) = (&sub_ops, &upd_ops);
            let (sub_fresh_ref, upd_fresh_ref) = (&sub_fresh, &upd_fresh);
            let done: Vec<(Side, TreeIndex)> =
                self.pool
                    .fan_map_take(workers, jobs, |_i, (side, k, mut tree)| {
                        let (ops, keys) = match side {
                            Side::Subscription => (sub_ops_ref, sub_fresh_ref),
                            Side::Update => (upd_ops_ref, upd_fresh_ref),
                        };
                        apply_dim_keys(&mut tree, k, ops, keys.as_deref());
                        (side, tree)
                    });
            for (side, tree) in done {
                match side {
                    Side::Subscription => self.sub_dims.push(tree),
                    Side::Update => self.upd_dims.push(tree),
                }
            }
        } else {
            for (k, tree) in self.sub_dims.iter_mut().enumerate() {
                apply_dim_keys(tree, k, &sub_ops, sub_fresh.as_deref());
            }
            for (k, tree) in self.upd_dims.iter_mut().enumerate() {
                apply_dim_keys(tree, k, &upd_ops, upd_fresh.as_deref());
            }
        }
        self.tracer
            .span(crate::obs::Phase::TreeWrite, t_tree, touched_count as u64);
        let t_recompute = self.tracer.start();

        // Phase B: recompute the post-apply overlap set of every
        // touched region (read-only tree queries; parallel for big
        // batches). The seed dimension is chosen per batch by the
        // native pipeline's sampled selectivity estimate, so a
        // low-selectivity dimension (e.g. a barely-discriminating time
        // axis) never seeds the candidate sets.
        let seed = seed_dim(&self.sub_dims, &self.upd_dims);
        let mut touched: Vec<(Side, u32)> = Vec::with_capacity(touched_count);
        touched.extend(sub_ops.keys().map(|&k| (Side::Subscription, k)));
        touched.extend(upd_ops.keys().map(|&k| (Side::Update, k)));
        // One (result, query-tmp) buffer pair per touched region, from
        // the scratch pool — warm epochs reuse their capacity.
        let mut bufs = self.scratch.take_u32_bufs(2 * touched.len());
        let mut items: Vec<(Vec<u32>, Vec<u32>)> = Vec::with_capacity(touched.len());
        while let (Some(a), Some(b)) = (bufs.pop(), bufs.pop()) {
            items.push((a, b));
        }
        let results: Vec<(Vec<u32>, Vec<u32>)> = if par && touched.len() > 1 {
            let sub_dims = &self.sub_dims;
            let upd_dims = &self.upd_dims;
            let touched_ref = &touched;
            let workers = self.nthreads.min(touched.len());
            self.pool
                .fan_map_take(workers, items, |i, (mut out, mut tmp)| {
                    let (side, key) = touched_ref[i];
                    recompute_into(sub_dims, upd_dims, side, key, seed, &mut out, &mut tmp);
                    (out, tmp)
                })
        } else {
            touched
                .iter()
                .zip(items)
                .map(|(&(side, key), (mut out, mut tmp))| {
                    recompute_into(
                        &self.sub_dims,
                        &self.upd_dims,
                        side,
                        key,
                        seed,
                        &mut out,
                        &mut tmp,
                    );
                    (out, tmp)
                })
                .collect()
        };

        self.tracer
            .span(crate::obs::Phase::Recompute, t_recompute, touched_count as u64);
        let t_diff = self.tracer.start();

        // Phase C: diff against the retained pair set and fold into the
        // epoch accumulator (serial; O(|diff|) set updates). The
        // gone/fresh work lists are pooled too — they used to be two
        // fresh allocations per touched region.
        let set_impl = self.params.set_impl;
        let key_hint = self.key_hint;
        let mut gone = self.scratch.take_u32();
        let mut fresh = self.scratch.take_u32();
        let mut ri = 0usize;
        for &skey in sub_ops.keys() {
            let new_upds = &results[ri].0;
            ri += 1;
            let old = self.sub_pairs.remove(&skey);
            gone.clear();
            if let Some(o) = &old {
                o.for_each(&mut |u| {
                    if new_upds.binary_search(&u).is_err() {
                        gone.push(u);
                    }
                });
            }
            fresh.clear();
            for &u in new_upds {
                let is_new = match &old {
                    Some(o) => !o.contains(u),
                    None => true,
                };
                if is_new {
                    fresh.push(u);
                }
            }
            for &u in &gone {
                if let Some(set) = self.upd_pairs.get_mut(&u) {
                    set.remove(skey);
                }
                self.n_pairs -= 1;
                self.note(pack_pair(skey, u), false);
            }
            for &u in &fresh {
                self.upd_pairs
                    .entry(u)
                    .or_insert_with(|| DynSet::new(set_impl, key_hint))
                    .insert(skey);
                self.n_pairs += 1;
                self.note(pack_pair(skey, u), true);
            }
            if !new_upds.is_empty() {
                let mut set = DynSet::new(set_impl, key_hint);
                for &u in new_upds {
                    set.insert(u);
                }
                self.sub_pairs.insert(skey, set);
            }
        }
        for &ukey in upd_ops.keys() {
            let new_subs = &results[ri].0;
            ri += 1;
            let old = self.upd_pairs.remove(&ukey);
            // Pairs whose subscription was ALSO touched this batch are
            // fully accounted by the subscription pass above — skip
            // them here so nothing is double-reported.
            gone.clear();
            if let Some(o) = &old {
                o.for_each(&mut |s| {
                    if !sub_ops.contains_key(&s) && new_subs.binary_search(&s).is_err() {
                        gone.push(s);
                    }
                });
            }
            fresh.clear();
            for &s in new_subs {
                if sub_ops.contains_key(&s) {
                    continue;
                }
                let is_new = match &old {
                    Some(o) => !o.contains(s),
                    None => true,
                };
                if is_new {
                    fresh.push(s);
                }
            }
            for &s in &gone {
                if let Some(set) = self.sub_pairs.get_mut(&s) {
                    set.remove(ukey);
                }
                self.n_pairs -= 1;
                self.note(pack_pair(s, ukey), false);
            }
            for &s in &fresh {
                self.sub_pairs
                    .entry(s)
                    .or_insert_with(|| DynSet::new(set_impl, key_hint))
                    .insert(ukey);
                self.n_pairs += 1;
                self.note(pack_pair(s, ukey), true);
            }
            if !new_subs.is_empty() {
                let mut set = DynSet::new(set_impl, key_hint);
                for &s in new_subs {
                    set.insert(s);
                }
                self.upd_pairs.insert(ukey, set);
            }
        }

        // Return every pooled buffer (cleared, capacity kept) — or
        // drop the whole scratch in cold mode.
        self.scratch.give_u32_bufs([gone, fresh]);
        self.scratch
            .give_u32_bufs(results.into_iter().flat_map(|(a, b)| [a, b]));
        if !self.params.reuse_scratch {
            self.scratch = MatchScratch::new();
        }
        self.dirty_since_publish = true;
        self.tracer.span(
            crate::obs::Phase::DiffMerge,
            t_diff,
            (self.acc_added.len() + self.acc_removed.len()) as u64,
        );
    }

    /// Fold one pair appearance/disappearance into the epoch
    /// accumulator; an appear + disappear of the same pair within one
    /// epoch cancels to nothing.
    fn note(&mut self, pair: u64, appeared: bool) {
        if appeared {
            if !self.acc_removed.remove(&pair) {
                self.acc_added.insert(pair);
            }
        } else if !self.acc_added.remove(&pair) {
            self.acc_removed.insert(pair);
        }
    }

    // ---- queries over the retained state -----------------------------------
    //
    // All of these answer from the *applied* state — staged ops not yet
    // applied (see `pending_ops`) are invisible until `commit`.

    /// Every currently intersecting (subscription key, update key)
    /// pair, sorted (equivalent to a full static match, but read from
    /// the retained set in O(K)).
    pub fn pairs(&self) -> PairVec {
        let mut out = Vec::with_capacity(self.n_pairs);
        for (&s, set) in &self.sub_pairs {
            set.for_each(&mut |u| out.push((s, u)));
        }
        out.sort_unstable();
        out
    }

    /// Update keys currently intersecting subscription `key`, sorted.
    pub fn updates_of(&self, sub_key: u32) -> Vec<u32> {
        self.sub_pairs
            .get(&sub_key)
            .map(|s| s.to_sorted_vec())
            .unwrap_or_default()
    }

    /// Subscription keys currently intersecting update `key`, sorted.
    pub fn subscriptions_of(&self, upd_key: u32) -> Vec<u32> {
        self.upd_pairs
            .get(&upd_key)
            .map(|s| s.to_sorted_vec())
            .unwrap_or_default()
    }

    /// Whether the pair currently intersects.
    pub fn contains_pair(&self, sub_key: u32, upd_key: u32) -> bool {
        self.sub_pairs
            .get(&sub_key)
            .is_some_and(|s| s.contains(upd_key))
    }

    /// The stored rectangle of subscription `key`, if live.
    pub fn subscription_rect(&self, key: u32) -> Option<Vec<Interval>> {
        rect_of(&self.sub_dims, key)
    }

    /// The stored rectangle of update `key`, if live.
    pub fn update_rect(&self, key: u32) -> Option<Vec<Interval>> {
        rect_of(&self.upd_dims, key)
    }
}

fn rect_of(dims: &[TreeIndex], key: u32) -> Option<Vec<Interval>> {
    let mut rect = Vec::with_capacity(dims.len());
    for dim in dims {
        rect.push(dim.get(key)?);
    }
    Some(rect)
}

/// Apply one side's coalesced batch to the dimension-`k` tree.
fn apply_dim(tree: &mut TreeIndex, k: usize, ops: &BTreeMap<u32, Option<Vec<Interval>>>) {
    for (&key, op) in ops {
        match op {
            Some(rect) => tree.put(key, rect[k]),
            None => tree.delete(key),
        }
    }
}

/// [`apply_dim`], restricted to `keys` when given: the pipelined-apply
/// path, where every other key in `ops` was already written to the
/// trees during the previous commit's overlap — only the freshly
/// staged keys (which override prewritten entries) still need their
/// `put`/`delete`.
fn apply_dim_keys(
    tree: &mut TreeIndex,
    k: usize,
    ops: &BTreeMap<u32, Option<Vec<Interval>>>,
    keys: Option<&[u32]>,
) {
    let Some(keys) = keys else {
        apply_dim(tree, k, ops);
        return;
    };
    for &key in keys {
        match &ops[&key] {
            Some(rect) => tree.put(key, rect[k]),
            None => tree.delete(key),
        }
    }
}

/// Merge a batch prewritten by a pipelined commit (tree entries
/// already current) with freshly staged ops (fresh wins per key).
/// Returns the merged batch plus the keys still needing phase-A tree
/// writes — `None` means "all of them" (the common, non-pipelined
/// path, kept allocation-free).
fn merge_batch(
    prewritten: BTreeMap<u32, Option<Vec<Interval>>>,
    fresh: BTreeMap<u32, Option<Vec<Interval>>>,
) -> (BTreeMap<u32, Option<Vec<Interval>>>, Option<Vec<u32>>) {
    if prewritten.is_empty() {
        return (fresh, None);
    }
    let fresh_keys: Vec<u32> = fresh.keys().copied().collect();
    let mut merged = prewritten;
    merged.extend(fresh);
    (merged, Some(fresh_keys))
}

/// Intervals sampled per tree by [`seed_dim`].
const SEED_SAMPLE: usize = 64;

/// Choose the seed (sweep) dimension for a batch's recompute queries —
/// the session spelling of the native N-D pipeline's sampled
/// selectivity estimate ([`crate::core::ddim::select_sweep_dim`]):
/// for each dimension, sample up to [`SEED_SAMPLE`] stored intervals
/// per side and score the expected 1-D hit fraction
/// `(E[l_sub] + E[l_upd]) / span`; the lowest score seeds the
/// candidate sets, so a barely-discriminating dimension never does.
fn seed_dim(sub_dims: &[TreeIndex], upd_dims: &[TreeIndex]) -> usize {
    let d = sub_dims.len();
    if d <= 1 {
        return 0;
    }
    let stat = |t: &TreeIndex| -> (f64, f64, f64, usize) {
        let (mut len, mut lo, mut hi, mut n) = (0.0f64, f64::INFINITY, f64::NEG_INFINITY, 0usize);
        for (_key, iv) in t.iter().take(SEED_SAMPLE) {
            len += iv.len();
            lo = lo.min(iv.lo);
            hi = hi.max(iv.hi);
            n += 1;
        }
        (len, lo, hi, n)
    };
    let mut best = 0;
    let mut best_score = f64::INFINITY;
    for k in 0..d {
        let (sl, slo, shi, sn) = stat(&sub_dims[k]);
        let (ul, ulo, uhi, un) = stat(&upd_dims[k]);
        let score = if sn == 0 || un == 0 {
            0.0
        } else {
            let mean = sl / sn as f64 + ul / un as f64;
            if mean <= 0.0 {
                0.0
            } else {
                let span = shi.max(uhi) - slo.min(ulo);
                mean / span.max(f64::MIN_POSITIVE)
            }
        };
        if score < best_score {
            best_score = score;
            best = k;
        }
    }
    best
}

/// Post-apply overlap set of one touched region, sweep-and-verify
/// style: seed with the `seed`-dimension query of the opposite side's
/// trees, then verify each residual dimension — per-key interval
/// lookups while the candidate set is small, tree query + sorted
/// intersection once it is large. Fills `out` with ascending
/// opposite-side keys (empty for a region removed this batch); both
/// `out` and the query buffer `tmp` are reusable scratch, so warm
/// epochs run this allocation-free.
fn recompute_into(
    sub_dims: &[TreeIndex],
    upd_dims: &[TreeIndex],
    side: Side,
    key: u32,
    seed: usize,
    out: &mut Vec<u32>,
    tmp: &mut Vec<u32>,
) {
    out.clear();
    let (own, opp) = match side {
        Side::Subscription => (sub_dims, upd_dims),
        Side::Update => (upd_dims, sub_dims),
    };
    let Some(iv_seed) = own[seed].get(key) else {
        return;
    };
    opp[seed].query_into(iv_seed, out);
    for k in 0..own.len() {
        if k == seed {
            continue;
        }
        if out.is_empty() {
            break;
        }
        let ivk = own[k].get(key).expect("per-dimension trees agree on keys");
        if out.len() <= 32 {
            out.retain(|&c| opp[k].get(c).is_some_and(|civ| civ.intersects(&ivk)));
        } else {
            opp[k].query_into(ivk, tmp);
            intersect_sorted_in_place(out, tmp);
        }
    }
}

/// In-place intersection of two ascending `u32` lists: `a ← a ∩ b`.
fn intersect_sorted_in_place(a: &mut Vec<u32>, b: &[u32]) {
    let (mut i, mut j, mut w) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                a[w] = a[i];
                w += 1;
                i += 1;
                j += 1;
            }
        }
    }
    a.truncate(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DdmEngine;
    use crate::prng::Rng;

    fn engine() -> DdmEngine {
        DdmEngine::builder().threads(2).build()
    }

    fn ivl(rng: &mut Rng) -> Interval {
        let lo = rng.uniform(0.0, 90.0);
        Interval::new(lo, lo + rng.uniform(0.5, 12.0))
    }

    #[test]
    fn empty_commit_is_empty() {
        let mut sess = engine().session(1);
        let d = sess.commit();
        assert!(d.is_empty());
        assert_eq!(d.epoch, 1);
        assert_eq!(sess.epoch(), 1);
        assert_eq!(sess.n_pairs(), 0);
        assert!(sess.pairs().is_empty());
    }

    #[test]
    fn single_pair_lifecycle() {
        let mut sess = engine().session(1);
        sess.upsert_subscription(5, &[Interval::new(0.0, 10.0)]);
        sess.upsert_update(9, &[Interval::new(5.0, 15.0)]);
        assert_eq!(sess.pending_ops(), 2);
        let d = sess.commit();
        assert_eq!(d.added, vec![(5, 9)]);
        assert!(d.removed.is_empty());
        assert_eq!(sess.n_pairs(), 1);
        assert!(sess.contains_pair(5, 9));
        assert_eq!(sess.updates_of(5), vec![9]);
        assert_eq!(sess.subscriptions_of(9), vec![5]);
        assert_eq!(sess.subscription_rect(5), Some(vec![Interval::new(0.0, 10.0)]));

        // Move the update away: the pair disappears.
        sess.upsert_update(9, &[Interval::new(50.0, 60.0)]);
        let d = sess.commit();
        assert_eq!(d.removed, vec![(5, 9)]);
        assert!(d.added.is_empty());
        assert_eq!(sess.n_pairs(), 0);
        assert!(!sess.contains_pair(5, 9));

        // Remove everything: nothing left, nothing reported.
        sess.remove_subscription(5);
        sess.remove_update(9);
        assert!(sess.commit().is_empty());
        assert_eq!(sess.n_subscriptions(), 0);
        assert_eq!(sess.n_updates(), 0);
        assert_eq!(sess.subscription_rect(5), None);
    }

    /// flush() makes staged state visible without closing the epoch or
    /// swallowing the pending diff.
    #[test]
    fn flush_preserves_pending_epoch_diff() {
        let mut sess = engine().session(1);
        sess.upsert_subscription(1, &[Interval::new(0.0, 10.0)]);
        sess.upsert_update(2, &[Interval::new(5.0, 15.0)]);
        sess.flush();
        assert_eq!(sess.pending_ops(), 0);
        assert_eq!(sess.n_pairs(), 1, "flushed state is readable");
        assert!(sess.contains_pair(1, 2));
        assert_eq!(sess.epoch(), 0, "flush does not close the epoch");
        let d = sess.commit();
        assert_eq!(d.added, vec![(1, 2)], "diff survives interleaved flush");
        assert_eq!(d.epoch, 1);
    }

    #[test]
    fn coalesced_same_epoch_churn_is_silent() {
        let mut sess = engine().session(1);
        sess.upsert_subscription(1, &[Interval::new(0.0, 10.0)]);
        sess.upsert_update(2, &[Interval::new(5.0, 15.0)]);
        sess.commit();
        // Away and back within one staged batch: last write wins, no diff.
        sess.upsert_update(2, &[Interval::new(100.0, 110.0)]);
        sess.upsert_update(2, &[Interval::new(5.0, 15.0)]);
        let d = sess.commit();
        assert!(d.is_empty(), "{d:?}");
        // Upsert then remove nets to a removal.
        sess.upsert_update(2, &[Interval::new(6.0, 16.0)]);
        sess.remove_update(2);
        let d = sess.commit();
        assert_eq!(d.removed, vec![(1, 2)]);
        assert!(d.added.is_empty());
    }

    #[test]
    fn auto_applied_batches_cancel_within_one_epoch() {
        // batch_threshold == 1: every staged op applies immediately, so
        // intra-epoch appear/disappear runs through the accumulator
        // cancellation (not last-write-wins coalescing).
        let mut sess = DdmEngine::builder()
            .threads(1)
            .batch_threshold(1)
            .build()
            .session(1);
        sess.upsert_subscription(1, &[Interval::new(0.0, 10.0)]);
        sess.upsert_update(2, &[Interval::new(5.0, 15.0)]); // pair appears
        sess.upsert_update(2, &[Interval::new(100.0, 110.0)]); // disappears
        sess.upsert_update(2, &[Interval::new(5.0, 15.0)]); // re-appears
        assert_eq!(sess.pending_ops(), 0, "threshold applies eagerly");
        let d = sess.commit();
        assert_eq!(d.added, vec![(1, 2)]);
        assert!(d.removed.is_empty());
        // A full away-and-back across applies nets to an empty diff.
        sess.upsert_update(2, &[Interval::new(100.0, 110.0)]);
        sess.upsert_update(2, &[Interval::new(5.0, 15.0)]);
        assert!(sess.commit().is_empty());
    }

    #[test]
    fn parallel_and_serial_sessions_agree() {
        let mut par = DdmEngine::builder()
            .threads(4)
            .parallel_cutoff(1)
            .build()
            .session(2);
        let mut ser = DdmEngine::builder().threads(1).build().session(2);
        let mut rng = Rng::new(0x5E01);
        for _epoch in 0..8 {
            for _ in 0..50 {
                let key = rng.below(40) as u32;
                let rect = [ivl(&mut rng), ivl(&mut rng)];
                match rng.below(4) {
                    0 | 1 => {
                        par.upsert_subscription(key, &rect);
                        ser.upsert_subscription(key, &rect);
                    }
                    2 => {
                        par.upsert_update(key, &rect);
                        ser.upsert_update(key, &rect);
                    }
                    _ => {
                        par.remove_subscription(key);
                        ser.remove_subscription(key);
                        par.remove_update(key);
                        ser.remove_update(key);
                    }
                }
            }
            let (dp, ds) = (par.commit(), ser.commit());
            assert_eq!(dp, ds);
            assert_eq!(par.pairs(), ser.pairs());
            assert_eq!(par.n_pairs(), ser.n_pairs());
        }
    }

    #[test]
    fn all_retention_set_impls_agree() {
        let mut sessions: Vec<DdmSession> = SetImpl::ALL
            .iter()
            .map(|&si| {
                DdmEngine::builder()
                    .threads(2)
                    .session_set_impl(si)
                    .build()
                    .session(1)
            })
            .collect();
        let mut rng = Rng::new(0x5E77);
        for _epoch in 0..5 {
            for _ in 0..60 {
                let key = rng.below(30) as u32;
                let iv = ivl(&mut rng);
                let roll = rng.below(4);
                for sess in &mut sessions {
                    match roll {
                        0 | 1 => sess.upsert_subscription(key, &[iv]),
                        2 => sess.upsert_update(key, &[iv]),
                        _ => sess.remove_update(key),
                    }
                }
            }
            let diffs: Vec<MatchDiff> = sessions.iter_mut().map(|s| s.commit()).collect();
            for d in &diffs[1..] {
                assert_eq!(d, &diffs[0]);
            }
            let pairs: Vec<PairVec> = sessions.iter().map(|s| s.pairs()).collect();
            for p in &pairs[1..] {
                assert_eq!(p, &pairs[0]);
            }
        }
    }

    /// The session's applied state tracks a brute-force oracle over
    /// random multi-dimensional op sequences, and accumulated diffs
    /// replay the oracle's pair set exactly.
    #[test]
    fn session_tracks_brute_force_property() {
        let engine = DdmEngine::builder().threads(2).parallel_cutoff(8).build();
        crate::bench::prop::prop_check("session-vs-brute-force", 0x5E02, |rng| {
            let d = 1 + rng.below(3) as usize;
            let mut sess = engine.session(d);
            let mut model_s: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
            let mut model_u: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
            let mut live: HashSet<(u32, u32)> = HashSet::new();
            for _epoch in 0..4 {
                for _ in 0..30 {
                    let key = rng.below(25) as u32;
                    let rect: Vec<Interval> = (0..d).map(|_| ivl(rng)).collect();
                    match rng.below(5) {
                        0 | 1 => {
                            sess.upsert_subscription(key, &rect);
                            model_s.insert(key, rect);
                        }
                        2 | 3 => {
                            sess.upsert_update(key, &rect);
                            model_u.insert(key, rect);
                        }
                        _ => {
                            if rng.chance(0.5) {
                                sess.remove_subscription(key);
                                model_s.remove(&key);
                            } else {
                                sess.remove_update(key);
                                model_u.remove(&key);
                            }
                        }
                    }
                }
                let diff = sess.commit();
                for &(s, u) in &diff.removed {
                    if !live.remove(&(s, u)) {
                        return Err(format!("removed non-live pair ({s}, {u})"));
                    }
                }
                for &(s, u) in &diff.added {
                    if !live.insert((s, u)) {
                        return Err(format!("added already-live pair ({s}, {u})"));
                    }
                }
                // Brute-force oracle over the model.
                let mut want: Vec<(u32, u32)> = Vec::new();
                for (&sk, srect) in &model_s {
                    for (&uk, urect) in &model_u {
                        if srect.iter().zip(urect).all(|(a, b)| a.intersects(b)) {
                            want.push((sk, uk));
                        }
                    }
                }
                want.sort_unstable();
                let mut acc: Vec<(u32, u32)> = live.iter().copied().collect();
                acc.sort_unstable();
                crate::bench::prop::expect_eq(&acc, &want, "accumulated diffs (d-dim)")?;
                crate::bench::prop::expect_eq(&sess.pairs(), &want, "retained pair set")?;
                if sess.n_pairs() != want.len() {
                    return Err(format!("n_pairs {} != oracle {}", sess.n_pairs(), want.len()));
                }
            }
            Ok(())
        });
    }

    /// region_count / retained_pair_count / epoch answer from applied
    /// state in O(1) — no diff accumulation needed by callers.
    #[test]
    fn introspection_is_cheap_and_current() {
        let mut sess = engine().session(1);
        sess.upsert_subscription(3, &[Interval::new(0.0, 10.0)]);
        sess.upsert_update(4, &[Interval::new(5.0, 15.0)]);
        assert_eq!(sess.region_count(Side::Subscription), 0, "staged ops are invisible");
        assert_eq!(sess.retained_pair_count(), 0);
        sess.commit();
        assert_eq!(sess.region_count(Side::Subscription), 1);
        assert_eq!(sess.region_count(Side::Update), 1);
        assert_eq!(sess.retained_pair_count(), 1);
        assert_eq!(sess.epoch(), 1);
        sess.remove_update(4);
        sess.flush();
        assert_eq!(sess.region_count(Side::Update), 0);
        assert_eq!(sess.retained_pair_count(), 0, "flush keeps counts current");
    }

    /// The recompute seed dimension follows selectivity: a 2-d session
    /// whose dimension 0 barely discriminates must seed from dimension
    /// 1 — and either way, results match the brute-force oracle.
    #[test]
    fn anisotropic_recompute_seeds_from_selective_dim() {
        let mut rng = Rng::new(0x5E99);
        let mut sess = engine().session(2);
        let mut model_s: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
        let mut model_u: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
        let mut rect = |rng: &mut Rng| {
            let wide = rng.uniform(0.0, 50.0);
            let sharp = rng.uniform(0.0, 99.0);
            vec![
                Interval::new(wide, wide + 50.0), // low selectivity
                Interval::new(sharp, sharp + 1.0), // high selectivity
            ]
        };
        for _epoch in 0..3 {
            for _ in 0..40 {
                let key = rng.below(40) as u32;
                let r = rect(&mut rng);
                if rng.chance(0.5) {
                    sess.upsert_subscription(key, &r);
                    model_s.insert(key, r);
                } else {
                    sess.upsert_update(key, &r);
                    model_u.insert(key, r);
                }
            }
            sess.commit();
            // The batch estimator sees the sharp dimension.
            assert_eq!(seed_dim(&sess.sub_dims, &sess.upd_dims), 1);
            let mut want: Vec<(u32, u32)> = Vec::new();
            for (&sk, sr) in &model_s {
                for (&uk, ur) in &model_u {
                    if sr.iter().zip(ur).all(|(a, b)| a.intersects(b)) {
                        want.push((sk, uk));
                    }
                }
            }
            want.sort_unstable();
            assert_eq!(sess.pairs(), want);
        }
    }

    #[test]
    fn intersect_sorted_basics() {
        let isect = |a: &[u32], b: &[u32]| -> Vec<u32> {
            let mut v = a.to_vec();
            intersect_sorted_in_place(&mut v, b);
            v
        };
        assert_eq!(isect(&[1, 3, 5, 9], &[2, 3, 9, 11]), vec![3, 9]);
        assert_eq!(isect(&[], &[1]), Vec::<u32>::new());
        assert_eq!(isect(&[7], &[7]), vec![7]);
    }

    /// Warm (scratch-reused) and cold (fresh-allocation) sessions
    /// produce identical diffs and pair sets across epochs, and the
    /// warm session's scratch stops growing once the churn pattern
    /// stabilizes.
    #[test]
    fn scratch_reuse_matches_cold_sessions_and_stops_growing() {
        let warm_engine = DdmEngine::builder().threads(2).parallel_cutoff(4).build();
        let cold_engine = DdmEngine::builder()
            .threads(2)
            .session_params(SessionParams {
                reuse_scratch: false,
                parallel_cutoff: 4,
                ..Default::default()
            })
            .build();
        let mut warm = warm_engine.session(2);
        let mut cold = cold_engine.session(2);
        let mut rng = Rng::new(0x5C0A);
        let mut stats = None;
        for epoch in 0..6 {
            for _ in 0..40 {
                let key = rng.below(30) as u32;
                let rect = [ivl(&mut rng), ivl(&mut rng)];
                match rng.below(4) {
                    0 | 1 => {
                        warm.upsert_subscription(key, &rect);
                        cold.upsert_subscription(key, &rect);
                    }
                    2 => {
                        warm.upsert_update(key, &rect);
                        cold.upsert_update(key, &rect);
                    }
                    _ => {
                        warm.remove_update(key);
                        cold.remove_update(key);
                    }
                }
            }
            let (dw, dc) = (warm.commit(), cold.commit());
            assert_eq!(dw, dc, "epoch {epoch} diffs diverged");
            assert_eq!(warm.pairs(), cold.pairs());
            // Cold sessions really drop their buffers.
            assert_eq!(cold.scratch_stats(), Default::default());
            // Warm buffer pool stabilizes after the first epochs (the
            // touched-region count per epoch is bounded by the key
            // space, so the pool stops acquiring new buffers).
            if epoch >= 3 {
                match stats {
                    None => stats = Some(warm.scratch_stats().pooled_u32_bufs),
                    Some(n) => {
                        assert!(
                            warm.scratch_stats().pooled_u32_bufs <= n.max(2 * 60 + 2),
                            "scratch pool kept growing: {} bufs",
                            warm.scratch_stats().pooled_u32_bufs
                        );
                    }
                }
            }
        }
    }

    /// Satellite regression: a pure reader never observes a flush side
    /// effect — read accessors leave staged ops staged and never swap
    /// the published snapshot.
    #[test]
    fn pure_readers_never_flush_staged_ops() {
        let mut sess = engine().session(1);
        sess.upsert_subscription(1, &[Interval::new(0.0, 10.0)]);
        sess.upsert_update(2, &[Interval::new(5.0, 15.0)]);
        let snap = sess.snapshot();
        assert_eq!(snap.readers(), 2, "this handle + the session's own");
        assert_eq!(sess.pending_ops(), 2);
        let _ = sess.pairs();
        let _ = sess.n_pairs();
        let _ = sess.region_count(Side::Subscription);
        let _ = sess.retained_pair_count();
        let _ = sess.updates_of(1);
        let _ = sess.contains_pair(1, 2);
        let _ = sess.snapshot();
        assert_eq!(sess.pending_ops(), 2, "reads must not apply staged ops");
        assert_eq!(snap.readers(), 2, "reads must not swap the snapshot");
        // A flush with nothing applied since the last publish is a
        // pure no-op too: same payload, no swap.
        sess.commit();
        let snap = sess.snapshot();
        assert_eq!(snap.readers(), 2);
        sess.flush();
        assert_eq!(snap.readers(), 2, "empty flush must not republish");
    }

    /// Snapshots published at commit equal every live read accessor,
    /// and an old snapshot stays bit-identical across K later commits
    /// and after the session is dropped.
    #[test]
    fn snapshots_track_live_state_and_stay_immutable() {
        let mut sess = engine().session(2);
        let mut rng = Rng::new(0xA11CE);
        let mut kept: Vec<(EpochSnapshot, PairVec)> = Vec::new();
        for _epoch in 0..6 {
            for _ in 0..40 {
                let key = rng.below(30) as u32;
                let rect = [ivl(&mut rng), ivl(&mut rng)];
                match rng.below(4) {
                    0 | 1 => sess.upsert_subscription(key, &rect),
                    2 => sess.upsert_update(key, &rect),
                    _ => sess.remove_update(key),
                }
            }
            sess.commit();
            let snap = sess.snapshot();
            assert_eq!(snap.epoch(), sess.epoch());
            assert_eq!(snap.pairs(), sess.pairs());
            assert_eq!(snap.n_pairs(), sess.n_pairs());
            assert_eq!(snap.n_subscriptions(), sess.n_subscriptions());
            assert_eq!(snap.n_updates(), sess.n_updates());
            for key in 0..30u32 {
                assert_eq!(snap.updates_of(key), sess.updates_of(key));
                assert_eq!(snap.subscriptions_of(key), sess.subscriptions_of(key));
                assert_eq!(
                    snap.contains_pair(key, (key + 1) % 30),
                    sess.contains_pair(key, (key + 1) % 30)
                );
            }
            kept.push((snap, sess.pairs()));
        }
        drop(sess);
        for (e, (snap, pairs)) in kept.iter().enumerate() {
            assert_eq!(snap.epoch(), e as u64 + 1);
            assert_eq!(&snap.pairs(), pairs, "snapshot of epoch {} changed", e + 1);
        }
    }

    /// flush publishes mid-epoch state under the still-open epoch
    /// number; commit republishes under the closed epoch's.
    #[test]
    fn flush_publishes_and_commit_advances_snapshot_epoch() {
        let mut sess = engine().session(1);
        sess.upsert_subscription(1, &[Interval::new(0.0, 10.0)]);
        sess.upsert_update(2, &[Interval::new(5.0, 15.0)]);
        assert!(sess.snapshot().is_empty(), "nothing published before flush");
        sess.flush();
        let mid = sess.snapshot();
        assert_eq!(mid.epoch(), 0, "flush keeps the epoch open");
        assert_eq!(mid.pairs(), vec![(1, 2)]);
        sess.upsert_update(2, &[Interval::new(50.0, 60.0)]);
        sess.commit();
        assert_eq!(sess.snapshot().epoch(), 1);
        assert!(sess.snapshot().pairs().is_empty());
        assert_eq!(mid.pairs(), vec![(1, 2)], "old handle still reads epoch-0 state");
    }

    /// A pipelined commit returns the same diffs and reaches the same
    /// state as a plain commit whose next batch is staged the ordinary
    /// way.
    #[test]
    fn pipelined_commit_agrees_with_plain_commit() {
        let mut pip = engine().session(2);
        let mut plain = engine().session(2);
        let mut rng = Rng::new(0x9199);
        for _epoch in 0..6 {
            for _ in 0..30 {
                let key = rng.below(25) as u32;
                let rect = [ivl(&mut rng), ivl(&mut rng)];
                match rng.below(4) {
                    0 | 1 => {
                        pip.upsert_subscription(key, &rect);
                        plain.upsert_subscription(key, &rect);
                    }
                    2 => {
                        pip.upsert_update(key, &rect);
                        plain.upsert_update(key, &rect);
                    }
                    _ => {
                        pip.remove_update(key);
                        plain.remove_update(key);
                    }
                }
            }
            // Next epoch's batch: prewritten through the pipelined
            // overlap on `pip`, staged the ordinary way on `plain`.
            let mut next_subs: BTreeMap<u32, Option<Vec<Interval>>> = BTreeMap::new();
            let mut next_upds: BTreeMap<u32, Option<Vec<Interval>>> = BTreeMap::new();
            for _ in 0..15 {
                let key = rng.below(25) as u32;
                let rect = vec![ivl(&mut rng), ivl(&mut rng)];
                match rng.below(3) {
                    0 => next_subs.insert(key, Some(rect)),
                    1 => next_upds.insert(key, Some(rect)),
                    _ => next_upds.insert(key, None),
                };
            }
            let dp = pip.commit_pipelined(next_subs.clone(), next_upds.clone());
            let dq = plain.commit();
            assert_eq!(dp, dq);
            assert_eq!(pip.pairs(), plain.pairs());
            assert_eq!(pip.snapshot().pairs(), plain.snapshot().pairs());
            for (k, op) in &next_subs {
                match op {
                    Some(r) => plain.upsert_subscription(*k, r),
                    None => plain.remove_subscription(*k),
                }
            }
            for (k, op) in &next_upds {
                match op {
                    Some(r) => plain.upsert_update(*k, r),
                    None => plain.remove_update(*k),
                }
            }
        }
        let (dp, dq) = (pip.commit(), plain.commit());
        assert_eq!(dp, dq, "final prewritten batch lands identically");
        assert_eq!(pip.pairs(), plain.pairs());
        assert_eq!(pip.snapshot(), plain.snapshot());
    }

    /// Ops drained from the MPSC front-end stage like direct calls,
    /// and a traced drain records one backlog_wait span.
    #[test]
    fn ingest_drain_stages_ops_and_records_backlog_wait() {
        let mut sess = DdmEngine::builder().threads(1).trace(true).build().session(1);
        let (tx, rx) = ingest_queue(8);
        tx.try_upsert(Side::Subscription, 1, &[Interval::new(0.0, 10.0)])
            .unwrap();
        tx.try_upsert(Side::Update, 2, &[Interval::new(5.0, 15.0)])
            .unwrap();
        tx.try_remove(Side::Update, 7).unwrap();
        assert_eq!(sess.drain_ingest(&rx), 3);
        assert_eq!(sess.pending_ops(), 3);
        assert_eq!(rx.depth(), 0, "drain empties the backlog gauge");
        let d = sess.commit();
        assert_eq!(d.added, vec![(1, 2)]);
        let spans = sess.drain_trace();
        let waits: Vec<_> = spans
            .iter()
            .filter(|s| s.phase == crate::obs::Phase::BacklogWait.id())
            .collect();
        assert_eq!(waits.len(), 1, "one span per non-empty drain");
        assert_eq!(waits[0].items, 3);
        assert_eq!(sess.drain_ingest(&rx), 0, "empty drain records nothing");
    }

    /// Every traced commit emits snapshot_swap + reader_pin spans that
    /// tile inside the commit envelope.
    #[test]
    fn traced_commit_emits_snapshot_swap_and_reader_pin() {
        let mut sess = DdmEngine::builder().threads(1).trace(true).build().session(1);
        sess.upsert_subscription(1, &[Interval::new(0.0, 10.0)]);
        sess.upsert_update(2, &[Interval::new(5.0, 15.0)]);
        let reader = sess.snapshot(); // pins the pre-commit payload
        sess.commit();
        let spans = sess.drain_trace();
        let find = |p: crate::obs::Phase| {
            spans
                .iter()
                .find(|s| s.phase == p.id())
                .unwrap_or_else(|| panic!("missing {} span", p.name()))
        };
        let env = find(crate::obs::Phase::Commit);
        let swap = find(crate::obs::Phase::SnapshotSwap);
        let pin = find(crate::obs::Phase::ReaderPin);
        assert!(
            swap.t0_ns >= env.t0_ns && swap.t1_ns <= env.t1_ns,
            "snapshot_swap tiles inside the commit envelope"
        );
        assert!(pin.t1_ns <= env.t1_ns);
        assert_eq!(pin.items, 1, "one reader handle pins the old payload");
        assert_eq!(swap.items, 1, "post-commit snapshot holds one pair");
        assert_eq!(reader.n_pairs(), 0, "pinned payload is the pre-commit one");
    }
}

//! Immutable per-epoch snapshots: the wait-free read side of the
//! session's MVCC split.
//!
//! [`EpochSnapshot`] is a refcounted, immutable view of one epoch's
//! applied match state — the retained pair set in both sort orders
//! plus the live region counts. The owning
//! [`DdmSession`](super::DdmSession) rebuilds one at every publish
//! point (flush / commit) and RCU-swaps it in; readers that cloned the
//! previous snapshot keep reading it untouched for as long as they
//! hold it, even across later commits or after the session is dropped.
//!
//! Every read on this type is lock-free and non-blocking by
//! construction: cloning is an `Arc` refcount bump and queries walk
//! immutable sorted slices. `xtask lint` enforces the invariant with
//! the `session-read-no-lock` rule — no `Mutex`/`RwLock` acquisition
//! may appear inside this file's fns.

use std::sync::Arc;

use super::Side;
use crate::core::sink::{pack_pair, unpack_pair, PairVec};

/// The shared immutable payload behind an [`EpochSnapshot`].
#[derive(Debug, Default, PartialEq, Eq)]
struct SnapInner {
    /// Epoch the snapshot was published at (flush publishes keep the
    /// still-open epoch's number).
    epoch: u64,
    /// Packed `(subscription key << 32) | update key` pairs, ascending
    /// — the subscription-major order [`pairs`](EpochSnapshot::pairs)
    /// and [`updates_of`](EpochSnapshot::updates_of) answer from.
    by_sub: Vec<u64>,
    /// The same pairs packed `(update key << 32) | subscription key`,
    /// ascending — the update-major order
    /// [`subscriptions_of`](EpochSnapshot::subscriptions_of) answers
    /// from.
    by_upd: Vec<u64>,
    /// Live subscription regions at publish time.
    n_subs: usize,
    /// Live update regions at publish time.
    n_upds: usize,
}

/// A wait-free, refcounted view of one epoch's applied match state.
///
/// Obtained from
/// [`DdmSession::snapshot`](super::DdmSession::snapshot) /
/// [`ShardedSession::snapshot`](crate::shard::ShardedSession::snapshot)
/// / [`AnySession::snapshot`](crate::shard::AnySession::snapshot).
/// Cloning is O(1); all queries read immutable sorted slices and the
/// answers never change, no matter what the session does afterwards.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EpochSnapshot {
    inner: Arc<SnapInner>,
}

/// Swap the two packed keys: `(hi << 32) | lo` → `(lo << 32) | hi`.
fn swap_packed(p: u64) -> u64 {
    (p << 32) | (p >> 32)
}

impl EpochSnapshot {
    /// Build a snapshot from an ascending, duplicate-free packed pair
    /// list (subscription-major, as produced by
    /// [`pack_pair`](crate::core::sink::pack_pair)).
    pub(crate) fn from_packed(epoch: u64, by_sub: Vec<u64>, n_subs: usize, n_upds: usize) -> Self {
        let mut by_upd: Vec<u64> = by_sub.iter().map(|&p| swap_packed(p)).collect();
        by_upd.sort_unstable();
        Self {
            inner: Arc::new(SnapInner {
                epoch,
                by_sub,
                by_upd,
                n_subs,
                n_upds,
            }),
        }
    }

    /// Merge per-shard snapshots into one global view: pairs are
    /// deduplicated (a boundary-straddling pair is retained by every
    /// shard it crosses), region counts are the caller's global ones
    /// (per-shard counts would double-count straddlers too).
    pub(crate) fn merge(epoch: u64, parts: &[EpochSnapshot], n_subs: usize, n_upds: usize) -> Self {
        let total: usize = parts.iter().map(|p| p.inner.by_sub.len()).sum();
        let mut by_sub: Vec<u64> = Vec::with_capacity(total);
        for part in parts {
            by_sub.extend_from_slice(&part.inner.by_sub);
        }
        by_sub.sort_unstable();
        by_sub.dedup();
        Self::from_packed(epoch, by_sub, n_subs, n_upds)
    }

    /// Epoch this snapshot was published at.
    pub fn epoch(&self) -> u64 {
        self.inner.epoch
    }

    /// Number of intersecting pairs in the snapshot.
    pub fn n_pairs(&self) -> usize {
        self.inner.by_sub.len()
    }

    /// `true` when the snapshot holds no pairs and no regions.
    pub fn is_empty(&self) -> bool {
        self.inner.by_sub.is_empty() && self.inner.n_subs == 0 && self.inner.n_upds == 0
    }

    /// Live subscription regions at publish time.
    pub fn n_subscriptions(&self) -> usize {
        self.inner.n_subs
    }

    /// Live update regions at publish time.
    pub fn n_updates(&self) -> usize {
        self.inner.n_upds
    }

    /// Live regions on one side at publish time.
    pub fn region_count(&self, side: Side) -> usize {
        match side {
            Side::Subscription => self.inner.n_subs,
            Side::Update => self.inner.n_upds,
        }
    }

    /// Every intersecting pair, sorted — identical to what
    /// [`DdmSession::pairs`](super::DdmSession::pairs) returned at the
    /// publish point.
    pub fn pairs(&self) -> PairVec {
        self.inner.by_sub.iter().map(|&p| unpack_pair(p)).collect()
    }

    /// The pairs in packed subscription-major form (ascending), no
    /// copy.
    pub fn packed_pairs(&self) -> &[u64] {
        &self.inner.by_sub
    }

    /// Whether the pair intersected at the publish point.
    pub fn contains_pair(&self, sub_key: u32, upd_key: u32) -> bool {
        self.inner
            .by_sub
            .binary_search(&pack_pair(sub_key, upd_key))
            .is_ok()
    }

    /// Update keys intersecting subscription `sub_key`, ascending.
    pub fn updates_of(&self, sub_key: u32) -> Vec<u32> {
        range_of(&self.inner.by_sub, sub_key)
    }

    /// Subscription keys intersecting update `upd_key`, ascending.
    pub fn subscriptions_of(&self, upd_key: u32) -> Vec<u32> {
        range_of(&self.inner.by_upd, upd_key)
    }

    /// How many handles (including this one) currently pin the
    /// snapshot's payload — the session reports the lingering count as
    /// the `reader_pin` span after each swap.
    pub fn readers(&self) -> usize {
        Arc::strong_count(&self.inner)
    }
}

/// Low halves of the contiguous run of packed keys whose high half is
/// `hi` (binary-searched range bounds on an ascending packed list).
fn range_of(packed: &[u64], hi: u32) -> Vec<u32> {
    let base = (hi as u64) << 32;
    let start = packed.partition_point(|&p| p < base);
    let end = packed.partition_point(|&p| p <= (base | u64::from(u32::MAX)));
    packed[start..end].iter().map(|&p| p as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(pairs: &[(u32, u32)]) -> EpochSnapshot {
        let mut packed: Vec<u64> = pairs.iter().map(|&(s, u)| pack_pair(s, u)).collect();
        packed.sort_unstable();
        packed.dedup();
        EpochSnapshot::from_packed(7, packed, 3, 4)
    }

    #[test]
    fn default_snapshot_is_empty_epoch_zero() {
        let s = EpochSnapshot::default();
        assert_eq!(s.epoch(), 0);
        assert!(s.is_empty());
        assert_eq!(s.n_pairs(), 0);
        assert!(s.pairs().is_empty());
        assert!(s.updates_of(0).is_empty());
        assert!(!s.contains_pair(0, 0));
    }

    #[test]
    fn queries_answer_both_sort_orders() {
        let s = snap(&[(1, 9), (1, 2), (5, 2), (0, 7)]);
        assert_eq!(s.epoch(), 7);
        assert_eq!(s.n_pairs(), 4);
        assert_eq!(s.n_subscriptions(), 3);
        assert_eq!(s.region_count(Side::Update), 4);
        assert_eq!(s.pairs(), vec![(0, 7), (1, 2), (1, 9), (5, 2)]);
        assert_eq!(s.updates_of(1), vec![2, 9]);
        assert_eq!(s.updates_of(4), Vec::<u32>::new());
        assert_eq!(s.subscriptions_of(2), vec![1, 5]);
        assert_eq!(s.subscriptions_of(7), vec![0]);
        assert!(s.contains_pair(1, 9));
        assert!(!s.contains_pair(9, 1));
    }

    #[test]
    fn boundary_keys_do_not_bleed_between_runs() {
        // Adjacent high halves with extreme low halves: the range scan
        // must not leak u32::MAX of one run into the next.
        let s = snap(&[(1, u32::MAX), (2, 0), (2, u32::MAX), (3, 0)]);
        assert_eq!(s.updates_of(1), vec![u32::MAX]);
        assert_eq!(s.updates_of(2), vec![0, u32::MAX]);
        assert_eq!(s.updates_of(3), vec![0]);
        assert_eq!(s.subscriptions_of(0), vec![2, 3]);
        assert_eq!(s.subscriptions_of(u32::MAX), vec![1, 2]);
    }

    #[test]
    fn merge_dedups_straddlers_and_keeps_global_counts() {
        let a = snap(&[(1, 2), (3, 4)]);
        let b = snap(&[(3, 4), (5, 6)]);
        let m = EpochSnapshot::merge(9, &[a, b], 10, 11);
        assert_eq!(m.epoch(), 9);
        assert_eq!(m.pairs(), vec![(1, 2), (3, 4), (5, 6)]);
        assert_eq!(m.n_subscriptions(), 10);
        assert_eq!(m.n_updates(), 11);
        assert_eq!(m.subscriptions_of(4), vec![3]);
    }

    #[test]
    fn clones_share_the_payload_and_count_readers() {
        let s = snap(&[(1, 2)]);
        assert_eq!(s.readers(), 1);
        let c = s.clone();
        assert_eq!(s.readers(), 2);
        assert_eq!(c.pairs(), s.pairs());
        drop(s);
        assert_eq!(c.readers(), 1);
        assert_eq!(c.pairs(), vec![(1, 2)]);
    }
}

//! Bit-vector active set (the paper's `boost::dynamic_bitset` analog).
//!
//! O(1) insert/remove/contains, O(universe/64) iteration and set
//! algebra with word-parallel operations. Memory is Θ(universe) bits
//! regardless of occupancy — the trade-off the paper's §4 GPU remarks
//! discuss.

use super::ActiveSet;

#[derive(Debug, Clone)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    #[inline]
    fn slot(id: u32) -> (usize, u64) {
        ((id >> 6) as usize, 1u64 << (id & 63))
    }
}

impl ActiveSet for BitSet {
    const NAME: &'static str = "bitvec";

    fn with_universe(universe: usize) -> Self {
        Self {
            words: vec![0; universe.div_ceil(64)],
            len: 0,
        }
    }

    #[inline]
    fn insert(&mut self, id: u32) {
        let (w, m) = Self::slot(id);
        let old = self.words[w];
        self.words[w] = old | m;
        self.len += usize::from(old & m == 0);
    }

    #[inline]
    fn remove(&mut self, id: u32) {
        let (w, m) = Self::slot(id);
        let old = self.words[w];
        self.words[w] = old & !m;
        self.len -= usize::from(old & m != 0);
    }

    #[inline]
    fn contains(&self, id: u32) -> bool {
        let (w, m) = Self::slot(id);
        self.words.get(w).is_some_and(|&x| x & m != 0)
    }

    fn len(&self) -> usize {
        self.len
    }

    fn clear(&mut self) {
        self.words.fill(0);
        self.len = 0;
    }

    fn for_each(&self, f: &mut dyn FnMut(u32)) {
        for (wi, &word) in self.words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                let bit = w.trailing_zeros();
                f((wi as u32) << 6 | bit);
                w &= w - 1;
            }
        }
    }

    /// Word-parallel union (overrides the per-element default).
    fn union_with(&mut self, other: &Self) {
        debug_assert_eq!(self.words.len(), other.words.len());
        let mut len = 0usize;
        for (a, &b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
            len += a.count_ones() as usize;
        }
        self.len = len;
    }

    /// Word-parallel difference (overrides the per-element default).
    fn subtract(&mut self, other: &Self) {
        debug_assert_eq!(self.words.len(), other.words.len());
        let mut len = 0usize;
        for (a, &b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
            len += a.count_ones() as usize;
        }
        self.len = len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_boundaries() {
        let mut s = BitSet::with_universe(130);
        for id in [0u32, 63, 64, 127, 128, 129] {
            s.insert(id);
            assert!(s.contains(id), "{id}");
        }
        assert_eq!(s.len(), 6);
        assert_eq!(s.to_sorted_vec(), vec![0, 63, 64, 127, 128, 129]);
    }

    #[test]
    fn word_parallel_algebra_keeps_len_consistent() {
        let mut a = BitSet::with_universe(256);
        let mut b = BitSet::with_universe(256);
        for i in (0..256).step_by(2) {
            a.insert(i);
        }
        for i in (0..256).step_by(3) {
            b.insert(i);
        }
        let mut u = a.clone();
        u.union_with(&b);
        assert_eq!(u.len(), u.to_sorted_vec().len());
        let mut d = a.clone();
        d.subtract(&b);
        assert_eq!(d.len(), d.to_sorted_vec().len());
        // |A \ B| + |A ∩ B| = |A|
        let inter = a.to_sorted_vec().iter().filter(|&&i| b.contains(i)).count();
        assert_eq!(d.len() + inter, a.len());
    }
}

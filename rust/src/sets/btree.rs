//! Ordered-tree active set (the paper's `std::set`, found fastest in
//! their C++ experiments; Rust's B-tree has far better cache behavior
//! than a red-black tree, so this is the strongest like-for-like).

use std::collections::BTreeSet;

use super::ActiveSet;

#[derive(Debug, Clone)]
pub struct BTreeActiveSet {
    inner: BTreeSet<u32>,
}

impl ActiveSet for BTreeActiveSet {
    const NAME: &'static str = "btree";

    fn with_universe(_universe: usize) -> Self {
        Self {
            inner: BTreeSet::new(),
        }
    }

    #[inline]
    fn insert(&mut self, id: u32) {
        self.inner.insert(id);
    }

    #[inline]
    fn remove(&mut self, id: u32) {
        self.inner.remove(&id);
    }

    #[inline]
    fn contains(&self, id: u32) -> bool {
        self.inner.contains(&id)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn clear(&mut self) {
        self.inner.clear();
    }

    fn for_each(&self, f: &mut dyn FnMut(u32)) {
        for &i in &self.inner {
            f(i);
        }
    }
}

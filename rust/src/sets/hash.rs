//! Hash-table active set (the paper's `std::unordered_set`).

use std::collections::HashSet;

use super::ActiveSet;

#[derive(Debug, Clone)]
pub struct HashActiveSet {
    inner: HashSet<u32>,
}

impl ActiveSet for HashActiveSet {
    const NAME: &'static str = "hash";

    fn with_universe(_universe: usize) -> Self {
        Self {
            inner: HashSet::new(),
        }
    }

    #[inline]
    fn insert(&mut self, id: u32) {
        self.inner.insert(id);
    }

    #[inline]
    fn remove(&mut self, id: u32) {
        self.inner.remove(&id);
    }

    #[inline]
    fn contains(&self, id: u32) -> bool {
        self.inner.contains(&id)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn clear(&mut self) {
        self.inner.clear();
    }

    fn for_each(&self, f: &mut dyn FnMut(u32)) {
        for &i in &self.inner {
            f(i);
        }
    }
}

//! Active-set data structures for the SBM sweep (paper §5).
//!
//! SBM and Parallel SBM track the sets of *active* subscription and
//! update regions; Parallel SBM additionally needs whole-set unions and
//! differences for the Algorithm 7 master combine. The paper compared
//! `std::vector<bool>`, raw bit vectors, `std::set` (red-black tree),
//! `std::unordered_set` (hash) and `boost::dynamic_bitset`, and found
//! `std::set` fastest on their workloads. We reproduce that study with
//! four Rust implementations behind one trait and re-measure in
//! `benches/abl_sets.rs` (see EXPERIMENTS.md §A1 for what changes in
//! Rust — spoiler: the bit vector wins at high densities, the BTree at
//! very low ones).

mod bitset;
mod btree;
mod hash;
mod sortedvec;
mod sparse;

pub use bitset::BitSet;
pub use btree::BTreeActiveSet;
pub use hash::HashActiveSet;
pub use sortedvec::SortedVecSet;
pub use sparse::SparseSet;

/// A set of region ids in a bounded universe `0..universe`.
///
/// All operations take `u32` region indices (the paper's regions are
/// dense arrays, so ids are indices, not keys).
pub trait ActiveSet: Clone + Send + 'static {
    /// Human-readable name for benches/tables.
    const NAME: &'static str;

    /// Empty set over `0..universe`.
    fn with_universe(universe: usize) -> Self;

    fn insert(&mut self, id: u32);
    fn remove(&mut self, id: u32);
    fn contains(&self, id: u32) -> bool;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn clear(&mut self);

    /// Visit every element (ascending order NOT guaranteed).
    fn for_each(&self, f: &mut dyn FnMut(u32));

    /// `self ← self ∪ other` (Algorithm 7 line 20).
    fn union_with(&mut self, other: &Self) {
        other.for_each(&mut |i| self.insert(i));
    }

    /// `self ← self \ other` (Algorithm 7 line 20).
    fn subtract(&mut self, other: &Self) {
        other.for_each(&mut |i| self.remove(i));
    }

    /// Collect to a sorted Vec (test/debug helper).
    fn to_sorted_vec(&self) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.len());
        self.for_each(&mut |i| v.push(i));
        v.sort_unstable();
        v
    }
}

/// Which set implementation to use (runtime-selectable for benches/CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetImpl {
    Bit,
    Hash,
    BTree,
    SortedVec,
    Sparse,
}

impl SetImpl {
    pub const ALL: [SetImpl; 5] = [
        SetImpl::Bit,
        SetImpl::Hash,
        SetImpl::BTree,
        SetImpl::SortedVec,
        SetImpl::Sparse,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SetImpl::Bit => BitSet::NAME,
            SetImpl::Hash => HashActiveSet::NAME,
            SetImpl::BTree => BTreeActiveSet::NAME,
            SetImpl::SortedVec => SortedVecSet::NAME,
            SetImpl::Sparse => SparseSet::NAME,
        }
    }
}

impl std::str::FromStr for SetImpl {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "bit" | "bitvec" => Ok(SetImpl::Bit),
            "hash" => Ok(SetImpl::Hash),
            "btree" | "set" => Ok(SetImpl::BTree),
            "sortedvec" | "vec" => Ok(SetImpl::SortedVec),
            "sparse" => Ok(SetImpl::Sparse),
            other => Err(format!("unknown set impl '{other}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn exercise<S: ActiveSet>() {
        let mut s = S::with_universe(1000);
        assert!(s.is_empty());
        s.insert(3);
        s.insert(999);
        s.insert(3); // duplicate insert is a no-op
        assert_eq!(s.len(), 2);
        assert!(s.contains(3) && s.contains(999) && !s.contains(4));
        s.remove(3);
        s.remove(3); // duplicate remove is a no-op
        assert_eq!(s.len(), 1);
        assert_eq!(s.to_sorted_vec(), vec![999]);
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn basic_ops_all_impls() {
        exercise::<BitSet>();
        exercise::<HashActiveSet>();
        exercise::<BTreeActiveSet>();
        exercise::<SortedVecSet>();
        exercise::<SparseSet>();
    }

    fn union_subtract<S: ActiveSet>() {
        let mut a = S::with_universe(100);
        let mut add = S::with_universe(100);
        let mut del = S::with_universe(100);
        for i in [1u32, 5, 9] {
            a.insert(i);
        }
        for i in [5u32, 20] {
            add.insert(i);
        }
        for i in [9u32, 50] {
            del.insert(i);
        }
        // (a ∪ add) \ del — Algorithm 7's master combine shape.
        a.union_with(&add);
        a.subtract(&del);
        assert_eq!(a.to_sorted_vec(), vec![1, 5, 20]);
    }

    #[test]
    fn union_subtract_all_impls() {
        union_subtract::<BitSet>();
        union_subtract::<HashActiveSet>();
        union_subtract::<BTreeActiveSet>();
        union_subtract::<SortedVecSet>();
        union_subtract::<SparseSet>();
    }

    /// Property: all four implementations agree under a random op
    /// sequence (the oracle is a model Vec<bool>).
    #[test]
    fn prop_impls_agree_with_model() {
        let universe = 256;
        let mut rng = Rng::new(0xABCD);
        for _case in 0..50 {
            let mut bit = BitSet::with_universe(universe);
            let mut hash = HashActiveSet::with_universe(universe);
            let mut btree = BTreeActiveSet::with_universe(universe);
            let mut sv = SortedVecSet::with_universe(universe);
            let mut sp = SparseSet::with_universe(universe);
            let mut model = vec![false; universe];
            for _op in 0..200 {
                let id = rng.below(universe as u64) as u32;
                if rng.chance(0.5) {
                    bit.insert(id);
                    hash.insert(id);
                    btree.insert(id);
                    sv.insert(id);
                    sp.insert(id);
                    model[id as usize] = true;
                } else {
                    bit.remove(id);
                    hash.remove(id);
                    btree.remove(id);
                    sv.remove(id);
                    sp.remove(id);
                    model[id as usize] = false;
                }
            }
            let want: Vec<u32> = (0..universe as u32)
                .filter(|&i| model[i as usize])
                .collect();
            assert_eq!(bit.to_sorted_vec(), want, "bit");
            assert_eq!(hash.to_sorted_vec(), want, "hash");
            assert_eq!(btree.to_sorted_vec(), want, "btree");
            assert_eq!(sv.to_sorted_vec(), want, "sortedvec");
            assert_eq!(sp.to_sorted_vec(), want, "sparse");
        }
    }

    #[test]
    fn set_impl_parses() {
        assert_eq!("bit".parse::<SetImpl>().unwrap(), SetImpl::Bit);
        assert_eq!("set".parse::<SetImpl>().unwrap(), SetImpl::BTree);
        assert_eq!("sparse".parse::<SetImpl>().unwrap(), SetImpl::Sparse);
        assert!("nope".parse::<SetImpl>().is_err());
    }
}

//! Active-set data structures for the SBM sweep (paper §5).
//!
//! SBM and Parallel SBM track the sets of *active* subscription and
//! update regions; Parallel SBM additionally needs whole-set unions and
//! differences for the Algorithm 7 master combine. The paper compared
//! `std::vector<bool>`, raw bit vectors, `std::set` (red-black tree),
//! `std::unordered_set` (hash) and `boost::dynamic_bitset`, and found
//! `std::set` fastest on their workloads. We reproduce that study with
//! four Rust implementations behind one trait and re-measure in
//! `benches/abl_sets.rs` (see EXPERIMENTS.md §A1 for what changes in
//! Rust — spoiler: the bit vector wins at high densities, the BTree at
//! very low ones).

mod bitset;
mod btree;
mod hash;
mod sortedvec;
mod sparse;

pub use bitset::BitSet;
pub use btree::BTreeActiveSet;
pub use hash::HashActiveSet;
pub use sortedvec::SortedVecSet;
pub use sparse::SparseSet;

/// A set of region ids in a bounded universe `0..universe`.
///
/// All operations take `u32` region indices (the paper's regions are
/// dense arrays, so ids are indices, not keys).
pub trait ActiveSet: Clone + Send + 'static {
    /// Human-readable name for benches/tables.
    const NAME: &'static str;

    /// Empty set over `0..universe`.
    fn with_universe(universe: usize) -> Self;

    fn insert(&mut self, id: u32);
    fn remove(&mut self, id: u32);
    fn contains(&self, id: u32) -> bool;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn clear(&mut self);

    /// Visit every element (ascending order NOT guaranteed).
    fn for_each(&self, f: &mut dyn FnMut(u32));

    /// `self ← self ∪ other` (Algorithm 7 line 20).
    fn union_with(&mut self, other: &Self) {
        other.for_each(&mut |i| self.insert(i));
    }

    /// `self ← self \ other` (Algorithm 7 line 20).
    fn subtract(&mut self, other: &Self) {
        other.for_each(&mut |i| self.remove(i));
    }

    /// Collect to a sorted Vec (test/debug helper).
    fn to_sorted_vec(&self) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.len());
        self.for_each(&mut |i| v.push(i));
        v.sort_unstable();
        v
    }
}

/// Which set implementation to use (runtime-selectable for benches/CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetImpl {
    Bit,
    Hash,
    BTree,
    SortedVec,
    Sparse,
}

impl SetImpl {
    pub const ALL: [SetImpl; 5] = [
        SetImpl::Bit,
        SetImpl::Hash,
        SetImpl::BTree,
        SetImpl::SortedVec,
        SetImpl::Sparse,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SetImpl::Bit => BitSet::NAME,
            SetImpl::Hash => HashActiveSet::NAME,
            SetImpl::BTree => BTreeActiveSet::NAME,
            SetImpl::SortedVec => SortedVecSet::NAME,
            SetImpl::Sparse => SparseSet::NAME,
        }
    }
}

impl std::str::FromStr for SetImpl {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "bit" | "bitvec" => Ok(SetImpl::Bit),
            "hash" => Ok(SetImpl::Hash),
            "btree" | "set" => Ok(SetImpl::BTree),
            "sortedvec" | "vec" => Ok(SetImpl::SortedVec),
            "sparse" => Ok(SetImpl::Sparse),
            other => Err(format!("unknown set impl '{other}'")),
        }
    }
}

/// A runtime-dispatched, growable active set over `u32` keys.
///
/// The static [`ActiveSet`] impls are monomorphized into the SBM/PSBM
/// hot loops and assume a universe fixed up front. The session layer
/// ([`crate::session`]) needs the same pluggable storage — the diff
/// retention set is selected by [`SetImpl`] at run time — but keyed by
/// long-lived region keys whose range grows as regions register.
/// `DynSet` wraps the five implementations behind enum dispatch and
/// transparently rebuilds on out-of-universe inserts (geometric
/// growth, amortized O(1)); out-of-universe `contains`/`remove` are
/// safe no-ops instead of panics.
#[derive(Debug, Clone)]
pub struct DynSet {
    universe: usize,
    imp: DynSetImpl,
}

#[derive(Debug, Clone)]
enum DynSetImpl {
    Bit(BitSet),
    Hash(HashActiveSet),
    BTree(BTreeActiveSet),
    SortedVec(SortedVecSet),
    Sparse(SparseSet),
}

impl DynSet {
    /// Empty set of the given implementation; `universe_hint` sizes the
    /// initial key range (growth handles underestimates).
    pub fn new(which: SetImpl, universe_hint: usize) -> Self {
        let universe = universe_hint.max(64);
        let imp = match which {
            SetImpl::Bit => DynSetImpl::Bit(BitSet::with_universe(universe)),
            SetImpl::Hash => DynSetImpl::Hash(HashActiveSet::with_universe(universe)),
            SetImpl::BTree => DynSetImpl::BTree(BTreeActiveSet::with_universe(universe)),
            SetImpl::SortedVec => DynSetImpl::SortedVec(SortedVecSet::with_universe(universe)),
            SetImpl::Sparse => DynSetImpl::Sparse(SparseSet::with_universe(universe)),
        };
        Self { universe, imp }
    }

    /// Which implementation backs this set.
    pub fn which(&self) -> SetImpl {
        match &self.imp {
            DynSetImpl::Bit(_) => SetImpl::Bit,
            DynSetImpl::Hash(_) => SetImpl::Hash,
            DynSetImpl::BTree(_) => SetImpl::BTree,
            DynSetImpl::SortedVec(_) => SetImpl::SortedVec,
            DynSetImpl::Sparse(_) => SetImpl::Sparse,
        }
    }

    fn grow_to(&mut self, min_universe: usize) {
        let mut bigger = DynSet::new(self.which(), min_universe.next_power_of_two());
        self.for_each(&mut |id| bigger.raw_insert(id));
        *self = bigger;
    }

    #[inline]
    fn raw_insert(&mut self, id: u32) {
        match &mut self.imp {
            DynSetImpl::Bit(s) => s.insert(id),
            DynSetImpl::Hash(s) => s.insert(id),
            DynSetImpl::BTree(s) => s.insert(id),
            DynSetImpl::SortedVec(s) => s.insert(id),
            DynSetImpl::Sparse(s) => s.insert(id),
        }
    }

    #[inline]
    pub fn insert(&mut self, id: u32) {
        if id as usize >= self.universe {
            self.grow_to(id as usize + 1);
        }
        self.raw_insert(id);
    }

    #[inline]
    pub fn remove(&mut self, id: u32) {
        if (id as usize) >= self.universe {
            return;
        }
        match &mut self.imp {
            DynSetImpl::Bit(s) => s.remove(id),
            DynSetImpl::Hash(s) => s.remove(id),
            DynSetImpl::BTree(s) => s.remove(id),
            DynSetImpl::SortedVec(s) => s.remove(id),
            DynSetImpl::Sparse(s) => s.remove(id),
        }
    }

    #[inline]
    pub fn contains(&self, id: u32) -> bool {
        if (id as usize) >= self.universe {
            return false;
        }
        match &self.imp {
            DynSetImpl::Bit(s) => s.contains(id),
            DynSetImpl::Hash(s) => s.contains(id),
            DynSetImpl::BTree(s) => s.contains(id),
            DynSetImpl::SortedVec(s) => s.contains(id),
            DynSetImpl::Sparse(s) => s.contains(id),
        }
    }

    pub fn len(&self) -> usize {
        match &self.imp {
            DynSetImpl::Bit(s) => s.len(),
            DynSetImpl::Hash(s) => s.len(),
            DynSetImpl::BTree(s) => s.len(),
            DynSetImpl::SortedVec(s) => s.len(),
            DynSetImpl::Sparse(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Visit every element (ascending order NOT guaranteed).
    pub fn for_each(&self, f: &mut dyn FnMut(u32)) {
        match &self.imp {
            DynSetImpl::Bit(s) => s.for_each(f),
            DynSetImpl::Hash(s) => s.for_each(f),
            DynSetImpl::BTree(s) => s.for_each(f),
            DynSetImpl::SortedVec(s) => s.for_each(f),
            DynSetImpl::Sparse(s) => s.for_each(f),
        }
    }

    pub fn to_sorted_vec(&self) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.len());
        self.for_each(&mut |i| v.push(i));
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn exercise<S: ActiveSet>() {
        let mut s = S::with_universe(1000);
        assert!(s.is_empty());
        s.insert(3);
        s.insert(999);
        s.insert(3); // duplicate insert is a no-op
        assert_eq!(s.len(), 2);
        assert!(s.contains(3) && s.contains(999) && !s.contains(4));
        s.remove(3);
        s.remove(3); // duplicate remove is a no-op
        assert_eq!(s.len(), 1);
        assert_eq!(s.to_sorted_vec(), vec![999]);
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn basic_ops_all_impls() {
        exercise::<BitSet>();
        exercise::<HashActiveSet>();
        exercise::<BTreeActiveSet>();
        exercise::<SortedVecSet>();
        exercise::<SparseSet>();
    }

    fn union_subtract<S: ActiveSet>() {
        let mut a = S::with_universe(100);
        let mut add = S::with_universe(100);
        let mut del = S::with_universe(100);
        for i in [1u32, 5, 9] {
            a.insert(i);
        }
        for i in [5u32, 20] {
            add.insert(i);
        }
        for i in [9u32, 50] {
            del.insert(i);
        }
        // (a ∪ add) \ del — Algorithm 7's master combine shape.
        a.union_with(&add);
        a.subtract(&del);
        assert_eq!(a.to_sorted_vec(), vec![1, 5, 20]);
    }

    #[test]
    fn union_subtract_all_impls() {
        union_subtract::<BitSet>();
        union_subtract::<HashActiveSet>();
        union_subtract::<BTreeActiveSet>();
        union_subtract::<SortedVecSet>();
        union_subtract::<SparseSet>();
    }

    /// Property: all four implementations agree under a random op
    /// sequence (the oracle is a model Vec<bool>).
    #[test]
    fn prop_impls_agree_with_model() {
        let universe = 256;
        let mut rng = Rng::new(0xABCD);
        for _case in 0..50 {
            let mut bit = BitSet::with_universe(universe);
            let mut hash = HashActiveSet::with_universe(universe);
            let mut btree = BTreeActiveSet::with_universe(universe);
            let mut sv = SortedVecSet::with_universe(universe);
            let mut sp = SparseSet::with_universe(universe);
            let mut model = vec![false; universe];
            for _op in 0..200 {
                let id = rng.below(universe as u64) as u32;
                if rng.chance(0.5) {
                    bit.insert(id);
                    hash.insert(id);
                    btree.insert(id);
                    sv.insert(id);
                    sp.insert(id);
                    model[id as usize] = true;
                } else {
                    bit.remove(id);
                    hash.remove(id);
                    btree.remove(id);
                    sv.remove(id);
                    sp.remove(id);
                    model[id as usize] = false;
                }
            }
            let want: Vec<u32> = (0..universe as u32)
                .filter(|&i| model[i as usize])
                .collect();
            assert_eq!(bit.to_sorted_vec(), want, "bit");
            assert_eq!(hash.to_sorted_vec(), want, "hash");
            assert_eq!(btree.to_sorted_vec(), want, "btree");
            assert_eq!(sv.to_sorted_vec(), want, "sortedvec");
            assert_eq!(sp.to_sorted_vec(), want, "sparse");
        }
    }

    #[test]
    fn set_impl_parses() {
        assert_eq!("bit".parse::<SetImpl>().unwrap(), SetImpl::Bit);
        assert_eq!("set".parse::<SetImpl>().unwrap(), SetImpl::BTree);
        assert_eq!("sparse".parse::<SetImpl>().unwrap(), SetImpl::Sparse);
        assert!("nope".parse::<SetImpl>().is_err());
        // Case-insensitive like Algo::from_str.
        assert_eq!("BIT".parse::<SetImpl>().unwrap(), SetImpl::Bit);
        assert_eq!("BTree".parse::<SetImpl>().unwrap(), SetImpl::BTree);
        assert_eq!(" Sparse ".parse::<SetImpl>().unwrap(), SetImpl::Sparse);
    }

    #[test]
    fn dyn_set_grows_past_initial_universe() {
        for si in SetImpl::ALL {
            let mut s = DynSet::new(si, 8);
            assert_eq!(s.which(), si);
            s.insert(3);
            s.insert(1000);
            s.insert(70_000);
            assert!(s.contains(3) && s.contains(1000) && s.contains(70_000));
            assert!(!s.contains(4));
            assert!(!s.contains(1_000_000)); // beyond universe: false, no panic
            s.remove(1_000_000); // beyond universe: no-op, no panic
            s.remove(1000);
            assert_eq!(s.to_sorted_vec(), vec![3, 70_000], "{}", si.name());
            assert_eq!(s.len(), 2);
            assert!(!s.is_empty());
        }
    }

    #[test]
    fn dyn_set_impls_agree_with_model() {
        let mut rng = Rng::new(0xD55);
        let mut sets: Vec<DynSet> = SetImpl::ALL.iter().map(|&si| DynSet::new(si, 16)).collect();
        let mut model = std::collections::BTreeSet::new();
        for _ in 0..500 {
            let id = rng.below(4096) as u32;
            if rng.chance(0.6) {
                for s in &mut sets {
                    s.insert(id);
                }
                model.insert(id);
            } else {
                for s in &mut sets {
                    s.remove(id);
                }
                model.remove(&id);
            }
        }
        let want: Vec<u32> = model.into_iter().collect();
        for s in &sets {
            assert_eq!(s.to_sorted_vec(), want, "{}", s.which().name());
            assert_eq!(s.len(), want.len());
        }
    }
}

//! Sorted-vector active set.
//!
//! Not in the paper's C++ candidate list, but the natural Rust
//! contender: contiguous memory, binary-search membership, O(k) splice
//! on insert/remove. Wins when active sets are small (low overlap
//! degree α), which is exactly the regime of the paper's α = 0.01
//! configuration.

use super::ActiveSet;

#[derive(Debug, Clone)]
pub struct SortedVecSet {
    inner: Vec<u32>,
}

impl ActiveSet for SortedVecSet {
    const NAME: &'static str = "sortedvec";

    fn with_universe(_universe: usize) -> Self {
        Self { inner: Vec::new() }
    }

    #[inline]
    fn insert(&mut self, id: u32) {
        if let Err(pos) = self.inner.binary_search(&id) {
            self.inner.insert(pos, id);
        }
    }

    #[inline]
    fn remove(&mut self, id: u32) {
        if let Ok(pos) = self.inner.binary_search(&id) {
            self.inner.remove(pos);
        }
    }

    #[inline]
    fn contains(&self, id: u32) -> bool {
        self.inner.binary_search(&id).is_ok()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn clear(&mut self) {
        self.inner.clear();
    }

    fn for_each(&self, f: &mut dyn FnMut(u32)) {
        for &i in &self.inner {
            f(i);
        }
    }

    /// Merge two sorted vectors (overrides the per-element default).
    fn union_with(&mut self, other: &Self) {
        if other.inner.is_empty() {
            return;
        }
        let mut merged = Vec::with_capacity(self.inner.len() + other.inner.len());
        let (a, b) = (&self.inner, &other.inner);
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => {
                    merged.push(a[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    merged.push(b[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    merged.push(a[i]);
                    i += 1;
                    j += 1;
                }
            }
        }
        merged.extend_from_slice(&a[i..]);
        merged.extend_from_slice(&b[j..]);
        self.inner = merged;
    }

    /// Linear-merge difference (overrides the per-element default).
    fn subtract(&mut self, other: &Self) {
        if other.inner.is_empty() {
            return;
        }
        let b = &other.inner;
        let mut j = 0;
        self.inner.retain(|&x| {
            while j < b.len() && b[j] < x {
                j += 1;
            }
            !(j < b.len() && b[j] == x)
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_union_and_subtract() {
        let mut a = SortedVecSet::with_universe(0);
        let mut b = SortedVecSet::with_universe(0);
        for i in [1u32, 3, 5, 7] {
            a.insert(i);
        }
        for i in [3u32, 4, 7, 9] {
            b.insert(i);
        }
        let mut u = a.clone();
        u.union_with(&b);
        assert_eq!(u.to_sorted_vec(), vec![1, 3, 4, 5, 7, 9]);
        let mut d = a.clone();
        d.subtract(&b);
        assert_eq!(d.to_sorted_vec(), vec![1, 5]);
    }
}

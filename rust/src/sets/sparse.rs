//! Sparse-set active set (Briggs & Torczon, "An efficient
//! representation for sparse sets", 1993) — the perf-pass winner.
//!
//! The SBM sweep calls `for_each` once per upper endpoint; with a bit
//! vector that costs O(universe/64) per call — O(N²/64) overall, which
//! measured 18 s at N = 10⁶ vs 0.5 s for tree sets (EXPERIMENTS.md
//! §Perf). The sparse set gives O(1) insert/remove/contains **and**
//! O(|active|) iteration: a dense array of members plus a
//! member→position index. Memory is Θ(universe) like the bit vector
//! (4 bytes/slot instead of 1 bit — the classic space/time trade).

use super::ActiveSet;

const NONE: u32 = u32::MAX;

#[derive(Debug, Clone)]
pub struct SparseSet {
    /// position of id in `dense`, or NONE.
    index: Vec<u32>,
    /// the members, packed.
    dense: Vec<u32>,
}

impl ActiveSet for SparseSet {
    const NAME: &'static str = "sparse";

    fn with_universe(universe: usize) -> Self {
        Self {
            index: vec![NONE; universe],
            dense: Vec::new(),
        }
    }

    #[inline]
    fn insert(&mut self, id: u32) {
        let slot = &mut self.index[id as usize];
        if *slot == NONE {
            *slot = self.dense.len() as u32;
            self.dense.push(id);
        }
    }

    #[inline]
    fn remove(&mut self, id: u32) {
        let pos = self.index[id as usize];
        if pos != NONE {
            let last = *self.dense.last().unwrap();
            self.dense[pos as usize] = last;
            self.index[last as usize] = pos;
            self.dense.pop();
            self.index[id as usize] = NONE;
        }
    }

    #[inline]
    fn contains(&self, id: u32) -> bool {
        self.index
            .get(id as usize)
            .is_some_and(|&p| p != NONE)
    }

    fn len(&self) -> usize {
        self.dense.len()
    }

    fn clear(&mut self) {
        for &id in &self.dense {
            self.index[id as usize] = NONE;
        }
        self.dense.clear();
    }

    #[inline]
    fn for_each(&self, f: &mut dyn FnMut(u32)) {
        for &id in &self.dense {
            f(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn swap_remove_bookkeeping() {
        let mut s = SparseSet::with_universe(10);
        for id in [3u32, 7, 1, 9] {
            s.insert(id);
        }
        s.remove(7); // middle removal swaps 9 into its slot
        assert!(!s.contains(7));
        assert!(s.contains(9) && s.contains(3) && s.contains(1));
        assert_eq!(s.len(), 3);
        assert_eq!(s.to_sorted_vec(), vec![1, 3, 9]);
        s.remove(9); // tail removal
        assert_eq!(s.to_sorted_vec(), vec![1, 3]);
        s.clear();
        assert!(s.is_empty());
        assert!(!s.contains(1));
    }

    #[test]
    fn iteration_cost_is_membership_bound() {
        // Smoke proxy for the O(|active|) claim: iterating an almost
        // empty set over a huge universe visits only the members.
        let mut s = SparseSet::with_universe(1_000_000);
        s.insert(5);
        s.insert(999_999);
        let mut visits = 0;
        s.for_each(&mut |_| visits += 1);
        assert_eq!(visits, 2);
    }

    #[test]
    fn double_insert_remove_are_noops() {
        let mut s = SparseSet::with_universe(4);
        s.insert(2);
        s.insert(2);
        assert_eq!(s.len(), 1);
        s.remove(2);
        s.remove(2);
        assert_eq!(s.len(), 0);
    }
}

//! [`ShardedMatcher`]: spatial sharding for the **static** matching
//! path, behind the same object-safe [`Matcher`] seam as every other
//! backend.
//!
//! The wrapper stripes dimension 0 of each call's workload into
//! `shards` uniform stripes (cuts derived from the call's own bounds),
//! replicates regions into every stripe they overlap, matches the
//! stripes **in parallel** with the wrapped matcher running serially
//! per stripe, and deduplicates boundary pairs with an owner rule: a
//! pair is reported only by the first stripe its *intersection*
//! overlaps — `max(first stripe of s, first stripe of u)` — which both
//! regions are guaranteed to inhabit, so every intersecting pair is
//! reported exactly once.
//!
//! Inner calls get a private zero-capacity pool (single-worker regions
//! only), keeping the engine pool's fan-out region the sole user of
//! real workers — nested parallel regions never happen.

use std::sync::Arc;

use crate::core::ddim::{self, NdMode, NdPolicy};
use crate::core::sink::{FnSink, MatchSink};
use crate::core::{RegionIdx, Regions1D, RegionsNd};
use crate::engine::{ExecCtx, Matcher};
use crate::exec::ThreadPool;

use super::partition::SpacePartitioner;

/// Per-stripe dense inputs plus the map back to global indices.
#[derive(Default)]
struct ShardInput {
    subs: Regions1D,
    sub_ids: Vec<RegionIdx>,
    upds: Regions1D,
    upd_ids: Vec<RegionIdx>,
}

/// A [`Matcher`] that stripes the workload across `shards` spatial
/// partitions and runs the wrapped matcher per stripe (in parallel
/// across stripes). Built automatically by
/// [`EngineBuilder::shards`](crate::engine::EngineBuilder::shards).
pub struct ShardedMatcher {
    inner: Arc<dyn Matcher>,
    shards: usize,
    name: String,
    /// N-D policy for this wrapper's own `match_nd` override (the
    /// stripes are 1-D calls, so the inner backend's policy never
    /// fires; the engine injects its policy here too).
    nd: NdPolicy,
    /// Zero-capacity pool for the serial inner calls — `run(1, _)`
    /// executes on the calling worker and never contends with the
    /// outer fan-out region.
    serial_pool: ThreadPool,
}

impl ShardedMatcher {
    pub fn new(inner: Arc<dyn Matcher>, shards: usize) -> Self {
        assert!(shards >= 1, "need at least one shard");
        let name = format!("sharded({}x{})", inner.name(), shards);
        Self {
            inner,
            shards,
            name,
            nd: NdPolicy::default(),
            serial_pool: ThreadPool::new(0),
        }
    }

    /// Set the N-D pipeline policy (engine-injected).
    pub fn with_nd(mut self, nd: NdPolicy) -> Self {
        self.nd = nd;
        self
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &Arc<dyn Matcher> {
        &self.inner
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Stripe one dimension's projections across the shards, run the
    /// inner matcher per stripe (serially, in parallel across stripes)
    /// and report owner-stripe pairs that survive `keep` to `sink`.
    /// `keep(s, u)` is the residual-dimension verification of the
    /// native N-D path (always true for plain 1-D matching).
    fn striped_match(
        &self,
        ctx: &ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        keep: &(dyn Fn(RegionIdx, RegionIdx) -> bool + Sync),
        sink: &mut dyn MatchSink,
    ) {
        let (Some(sb), Some(ub)) = (subs.bounds(), upds.bounds()) else {
            return; // one side empty: nothing can intersect
        };
        let span = sb.hull(&ub);
        if self.shards <= 1 || span.len() <= 0.0 {
            let mut fsink = FnSink(|s: u32, u: u32| {
                if keep(s, u) {
                    sink.report(s, u);
                }
            });
            return self.inner.match_1d(ctx, subs, upds, &mut fsink);
        }
        let part = SpacePartitioner::uniform(self.shards, 0, span);

        // Route (replicating stripe-straddlers) and record each
        // region's first stripe for the owner rule.
        let mut inputs: Vec<ShardInput> = (0..self.shards).map(|_| ShardInput::default()).collect();
        let mut sub_first: Vec<u32> = Vec::with_capacity(subs.len());
        for i in 0..subs.len() {
            let iv = subs.get(i);
            let (a, b) = part.route(iv);
            sub_first.push(a as u32);
            for input in &mut inputs[a..=b] {
                input.subs.push(iv);
                input.sub_ids.push(i as RegionIdx);
            }
        }
        let mut upd_first: Vec<u32> = Vec::with_capacity(upds.len());
        for j in 0..upds.len() {
            let iv = upds.get(j);
            let (a, b) = part.route(iv);
            upd_first.push(a as u32);
            for input in &mut inputs[a..=b] {
                input.upds.push(iv);
                input.upd_ids.push(j as RegionIdx);
            }
        }

        // Match one stripe serially, keeping only owner-stripe pairs
        // that survive the residual check.
        let run_shard = |i: usize| -> Vec<(RegionIdx, RegionIdx)> {
            let input = &inputs[i];
            if input.subs.is_empty() || input.upds.is_empty() {
                return Vec::new();
            }
            let sctx = ExecCtx::new(&self.serial_pool, 1);
            let mut out = Vec::new();
            {
                let mut fsink = FnSink(|ls: u32, lu: u32| {
                    let s = input.sub_ids[ls as usize];
                    let u = input.upd_ids[lu as usize];
                    if sub_first[s as usize].max(upd_first[u as usize]) as usize == i && keep(s, u)
                    {
                        out.push((s, u));
                    }
                });
                self.inner.match_1d(&sctx, &input.subs, &input.upds, &mut fsink);
            }
            out
        };

        let workers = ctx.nthreads.min(self.shards).max(1);
        let shard_pairs: Vec<Vec<(RegionIdx, RegionIdx)>> = if workers > 1 {
            ctx.pool.fan_map(workers, self.shards, |i| run_shard(i))
        } else {
            (0..self.shards).map(run_shard).collect()
        };
        for pairs in shard_pairs {
            for (s, u) in pairs {
                sink.report(s, u);
            }
        }
    }
}

impl Matcher for ShardedMatcher {
    fn name(&self) -> &str {
        &self.name
    }

    fn match_1d(
        &self,
        ctx: &ExecCtx<'_>,
        subs: &Regions1D,
        upds: &Regions1D,
        sink: &mut dyn MatchSink,
    ) {
        self.striped_match(ctx, subs, upds, &|_s, _u| true, sink);
    }

    /// Native sweep-and-verify across the stripes: stripe the chosen
    /// sweep dimension's projections, run the inner 1-D matcher per
    /// stripe, and fold the residual-dimension verification into the
    /// per-stripe owner-rule filter — so sharding and the native N-D
    /// pipeline compose without materializing any per-dimension pair
    /// set. `--nd-mode reduce` falls back to the per-dimension
    /// reduction over the sharded 1-D path.
    fn match_nd(
        &self,
        ctx: &ExecCtx<'_>,
        subs: &RegionsNd,
        upds: &RegionsNd,
        sink: &mut dyn MatchSink,
    ) {
        assert_eq!(subs.d(), upds.d(), "dimension mismatch");
        match self.nd.mode {
            NdMode::Reduction => ddim::ReductionNd::match_nd_with(
                Some(ctx.pool),
                subs,
                upds,
                |s1, u1, out| self.match_1d(ctx, s1, u1, out),
                sink,
            ),
            NdMode::Native => {
                let k =
                    ddim::resolve_sweep_dim(self.nd.sweep, ctx.pool, ctx.nthreads, subs, upds);
                let keep = |s: RegionIdx, u: RegionIdx| -> bool {
                    subs.rects_intersect_except(s as usize, upds, u as usize, k)
                };
                self.striped_match(ctx, subs.project(k), upds.project(k), &keep, sink);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::Algo;
    use crate::core::interval::Interval;
    use crate::core::region::random_regions_1d;
    use crate::engine::{algo_matcher, DdmEngine};
    use crate::prng::Rng;

    #[test]
    fn sharded_matcher_agrees_with_plain_backend() {
        let mut rng = Rng::new(0x5AA0);
        let subs = random_regions_1d(&mut rng, 400, 500.0, 12.0);
        let upds = random_regions_1d(&mut rng, 350, 500.0, 9.0);
        let plain = DdmEngine::builder().algo(Algo::Psbm).threads(2).build();
        let want = plain.pairs_1d(&subs, &upds);
        assert!(!want.is_empty());
        for shards in [1usize, 2, 3, 8] {
            let engine = DdmEngine::builder().algo(Algo::Psbm).threads(2).shards(shards).build();
            assert_eq!(engine.pairs_1d(&subs, &upds), want, "shards={shards}");
            assert_eq!(engine.count_1d(&subs, &upds), want.len() as u64, "shards={shards}");
        }
    }

    #[test]
    fn owner_rule_dedups_wide_regions() {
        // One subscription spanning the whole space intersects every
        // update exactly once no matter how many stripes replicate it.
        let subs = Regions1D::from_intervals(&[Interval::new(0.0, 100.0)]);
        let upds = Regions1D::from_intervals(&[
            Interval::new(5.0, 15.0),
            Interval::new(45.0, 55.0), // straddles the 2-shard cut
            Interval::new(90.0, 99.0),
        ]);
        for shards in [2usize, 4, 7] {
            let engine = DdmEngine::builder().algo(Algo::Bfm).threads(2).shards(shards).build();
            assert_eq!(
                engine.pairs_1d(&subs, &upds),
                vec![(0, 0), (0, 1), (0, 2)],
                "shards={shards}"
            );
        }
    }

    #[test]
    fn nd_reduction_composes_with_sharding() {
        let mut rng = Rng::new(0x5AA1);
        let d = 3;
        let mut subs = crate::core::RegionsNd::new(d);
        let mut upds = crate::core::RegionsNd::new(d);
        for _ in 0..120 {
            let rect: Vec<Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 80.0);
                    Interval::new(lo, lo + rng.uniform(0.5, 25.0))
                })
                .collect();
            subs.push(&rect);
        }
        for _ in 0..100 {
            let rect: Vec<Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 80.0);
                    Interval::new(lo, lo + rng.uniform(0.5, 25.0))
                })
                .collect();
            upds.push(&rect);
        }
        let plain = DdmEngine::builder().algo(Algo::Itm).threads(2).build();
        let want = plain.pairs_nd(&subs, &upds);
        assert!(!want.is_empty());
        // Native sweep-and-verify across stripes (the default)…
        let sharded = DdmEngine::builder().algo(Algo::Itm).threads(2).shards(5).build();
        assert_eq!(sharded.pairs_nd(&subs, &upds), want);
        assert_eq!(sharded.count_nd(&subs, &upds), want.len() as u64);
        // …and the per-dimension reduction fallback over sharded 1-D.
        let reduce = DdmEngine::builder()
            .algo(Algo::Itm)
            .threads(2)
            .shards(5)
            .nd_mode(crate::engine::NdMode::Reduction)
            .build();
        assert_eq!(reduce.pairs_nd(&subs, &upds), want);
        // Pinned sweep dimensions agree too.
        for k in 0..d {
            let pinned = DdmEngine::builder()
                .algo(Algo::Itm)
                .threads(2)
                .shards(3)
                .sweep_dim(crate::engine::SweepDim::Fixed(k))
                .build();
            assert_eq!(pinned.pairs_nd(&subs, &upds), want, "sweep dim {k}");
        }
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let m = ShardedMatcher::new(algo_matcher(Algo::Bfm, &Default::default()), 4);
        assert_eq!(m.shards(), 4);
        assert!(m.name().contains("bfm"));
        let pool = ThreadPool::new(1);
        let ctx = ExecCtx::new(&pool, 2);
        let mut sink = crate::core::sink::VecSink::default();
        m.match_1d(&ctx, &Regions1D::default(), &Regions1D::default(), &mut sink);
        assert!(sink.pairs.is_empty());
        // Zero-width span (all points identical) falls through to the
        // plain backend.
        let pt = Regions1D::from_intervals(&[Interval::new(5.0, 5.0)]);
        m.match_1d(&ctx, &pt, &pt, &mut sink);
        assert!(sink.pairs.is_empty(), "empty intervals never intersect");
    }
}

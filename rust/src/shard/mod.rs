//! Sharded matching: spatial partitions with per-shard sessions and
//! merged cross-shard diffs.
//!
//! The paper parallelizes one shared match over a single address
//! space; its predecessor line (*A Parallel Data Distribution
//! Management Algorithm*, arXiv:1309.3458) exploits the complementary
//! axis — partition the **routing space** itself so disjoint
//! sub-problems match independently. This module adds that layer
//! between the service and the session:
//!
//! * [`SpacePartitioner`] — stripes one split dimension (uniform cuts
//!   over a span, or sample-based balanced quantile cuts) and routes
//!   each region to every stripe its extent overlaps.
//! * [`ShardedSession`] — one inner
//!   [`DdmSession`](crate::session::DdmSession) per stripe; staged ops
//!   fan out to owning shards (with boundary-crossing regions
//!   re-routed), epochs commit shard-parallel on the
//!   [`exec`](crate::exec) pool, per-shard
//!   [`MatchDiff`](crate::session::MatchDiff)s merge through global
//!   pair refcounts into one deduplicated diff.
//! * [`ShardedMatcher`] — the static-path counterpart: a
//!   [`Matcher`](crate::engine::Matcher) wrapper that stripes each
//!   call's workload and dedups with an owner-stripe rule.
//! * [`AnySession`] — runtime dispatch between a plain session and a
//!   sharded one, so the HLA service and the CLI stay agnostic of the
//!   builder's [`shards`](crate::engine::EngineBuilder::shards)
//!   setting.
//!
//! Everything is wired through the engine:
//! `DdmEngine::builder().shards(8).split_dim(0)` makes
//! [`DdmEngine::sharded_session`](crate::engine::DdmEngine::sharded_session)
//! / [`any_session`](crate::engine::DdmEngine::any_session) hand out
//! sharded state and wraps the static matcher in a [`ShardedMatcher`].

pub mod matcher;
pub mod partition;
pub mod session;

pub use matcher::ShardedMatcher;
pub use partition::SpacePartitioner;
pub use session::{ShardStats, ShardedSession};

use crate::core::interval::Interval;
use crate::core::sink::PairVec;
use crate::core::{Regions1D, RegionsNd};
use crate::session::{DdmSession, EpochSnapshot, IngestReceiver, MatchDiff, SessionParams};

/// How a sharded session derives its stripe cuts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardStrategy {
    /// Equal-width stripes over the configured span.
    #[default]
    Uniform,
    /// Quantile cuts sampled from the first staged batch (stripes hold
    /// roughly equal region counts even under skew); uniform cuts
    /// serve as the fallback until data arrives.
    Balanced,
}

/// Runtime dispatch between a plain [`DdmSession`] and a
/// [`ShardedSession`], exposing the shared staging/commit/read surface
/// consumers (the HLA service, `ddm replay`) program against. Built by
/// [`DdmEngine::any_session`](crate::engine::DdmEngine::any_session).
pub enum AnySession {
    Single(DdmSession),
    Sharded(ShardedSession),
}

impl AnySession {
    pub fn d(&self) -> usize {
        match self {
            AnySession::Single(s) => s.d(),
            AnySession::Sharded(s) => s.d(),
        }
    }

    /// Number of shards (`1` for the unsharded path).
    pub fn shards(&self) -> usize {
        match self {
            AnySession::Single(_) => 1,
            AnySession::Sharded(s) => s.shards(),
        }
    }

    /// Number of committed epochs.
    pub fn epoch(&self) -> u64 {
        match self {
            AnySession::Single(s) => s.epoch(),
            AnySession::Sharded(s) => s.epoch(),
        }
    }

    /// Staged (coalesced) ops not yet applied.
    pub fn pending_ops(&self) -> usize {
        match self {
            AnySession::Single(s) => s.pending_ops(),
            AnySession::Sharded(s) => s.pending_ops(),
        }
    }

    pub fn n_subscriptions(&self) -> usize {
        match self {
            AnySession::Single(s) => s.n_subscriptions(),
            AnySession::Sharded(s) => s.n_subscriptions(),
        }
    }

    pub fn n_updates(&self) -> usize {
        match self {
            AnySession::Single(s) => s.n_updates(),
            AnySession::Sharded(s) => s.n_updates(),
        }
    }

    /// Retained intersecting pairs (sharded: globally merged count as
    /// of the last commit).
    pub fn n_pairs(&self) -> usize {
        match self {
            AnySession::Single(s) => s.n_pairs(),
            AnySession::Sharded(s) => s.n_pairs(),
        }
    }

    pub fn upsert_subscription(&mut self, key: u32, rect: &[Interval]) {
        match self {
            AnySession::Single(s) => s.upsert_subscription(key, rect),
            AnySession::Sharded(s) => s.upsert_subscription(key, rect),
        }
    }

    pub fn upsert_update(&mut self, key: u32, rect: &[Interval]) {
        match self {
            AnySession::Single(s) => s.upsert_update(key, rect),
            AnySession::Sharded(s) => s.upsert_update(key, rect),
        }
    }

    pub fn remove_subscription(&mut self, key: u32) {
        match self {
            AnySession::Single(s) => s.remove_subscription(key),
            AnySession::Sharded(s) => s.remove_subscription(key),
        }
    }

    pub fn remove_update(&mut self, key: u32) {
        match self {
            AnySession::Single(s) => s.remove_update(key),
            AnySession::Sharded(s) => s.remove_update(key),
        }
    }

    /// Stage a whole 1-D workload keyed by dense index.
    pub fn load_dense_1d(&mut self, subs: &Regions1D, upds: &Regions1D) {
        match self {
            AnySession::Single(s) => s.load_dense_1d(subs, upds),
            AnySession::Sharded(s) => s.load_dense_1d(subs, upds),
        }
    }

    /// Stage a whole d-dimensional workload keyed by dense index.
    pub fn load_dense(&mut self, subs: &RegionsNd, upds: &RegionsNd) {
        match self {
            AnySession::Single(s) => s.load_dense(subs, upds),
            AnySession::Sharded(s) => s.load_dense(subs, upds),
        }
    }

    /// Apply staged ops without closing the epoch.
    pub fn flush(&mut self) {
        match self {
            AnySession::Single(s) => s.flush(),
            AnySession::Sharded(s) => s.flush(),
        }
    }

    /// Apply staged ops and close the epoch, returning the (sharded:
    /// merged, deduplicated) intersection delta.
    pub fn commit(&mut self) -> MatchDiff {
        match self {
            AnySession::Single(s) => s.commit(),
            AnySession::Sharded(s) => s.commit(),
        }
    }

    /// The current wait-free read snapshot (sharded: the cached merge
    /// of every shard's snapshot). O(1); the handle stays valid and
    /// bit-identical across later commits.
    pub fn snapshot(&self) -> EpochSnapshot {
        match self {
            AnySession::Single(s) => s.snapshot(),
            AnySession::Sharded(s) => s.snapshot(),
        }
    }

    /// Drain a bounded ingest queue into the staging maps; returns the
    /// drained count (see
    /// [`ingest_queue`](crate::session::ingest_queue)).
    pub fn drain_ingest(&mut self, rx: &IngestReceiver) -> usize {
        match self {
            AnySession::Single(s) => s.drain_ingest(rx),
            AnySession::Sharded(s) => s.drain_ingest(rx),
        }
    }

    /// The parameters the session was built with.
    pub fn params(&self) -> SessionParams {
        match self {
            AnySession::Single(s) => s.params(),
            AnySession::Sharded(s) => s.params(),
        }
    }

    /// Every currently intersecting pair, sorted and duplicate-free.
    pub fn pairs(&self) -> PairVec {
        match self {
            AnySession::Single(s) => s.pairs(),
            AnySession::Sharded(s) => s.pairs(),
        }
    }

    pub fn updates_of(&self, sub_key: u32) -> Vec<u32> {
        match self {
            AnySession::Single(s) => s.updates_of(sub_key),
            AnySession::Sharded(s) => s.updates_of(sub_key),
        }
    }

    pub fn subscriptions_of(&self, upd_key: u32) -> Vec<u32> {
        match self {
            AnySession::Single(s) => s.subscriptions_of(upd_key),
            AnySession::Sharded(s) => s.subscriptions_of(upd_key),
        }
    }

    pub fn contains_pair(&self, sub_key: u32, upd_key: u32) -> bool {
        match self {
            AnySession::Single(s) => s.contains_pair(sub_key, upd_key),
            AnySession::Sharded(s) => s.contains_pair(sub_key, upd_key),
        }
    }

    /// Per-shard load snapshot (`None` on the unsharded path).
    pub fn shard_stats(&self) -> Option<Vec<ShardStats>> {
        match self {
            AnySession::Single(_) => None,
            AnySession::Sharded(s) => Some(s.shard_stats()),
        }
    }

    /// Shard load imbalance gauge (`None` on the unsharded path).
    pub fn imbalance(&self) -> Option<f64> {
        match self {
            AnySession::Single(_) => None,
            AnySession::Sharded(s) => Some(s.imbalance()),
        }
    }

    /// Whether phase spans are being captured
    /// ([`SessionParams::trace`](crate::session::SessionParams::trace)).
    pub fn trace_enabled(&self) -> bool {
        match self {
            AnySession::Single(s) => s.trace_enabled(),
            AnySession::Sharded(s) => s.trace_enabled(),
        }
    }

    /// Take the phase spans recorded since the last drain (empty when
    /// tracing is off). Single sessions put commit phases on the
    /// master lane; sharded sessions put each shard's phases on lane =
    /// shard id under a per-shard
    /// [`ShardCommit`](crate::obs::Phase::ShardCommit) envelope.
    pub fn drain_trace(&mut self) -> Vec<crate::obs::SpanRecord> {
        match self {
            AnySession::Single(s) => s.drain_trace(),
            AnySession::Sharded(s) => s.drain_trace(),
        }
    }

    /// Spans lost to full trace buffers since construction.
    pub fn trace_dropped(&self) -> u64 {
        match self {
            AnySession::Single(s) => s.trace_dropped(),
            AnySession::Sharded(s) => s.trace_dropped(),
        }
    }

    // ---- durability ---------------------------------------------------------

    /// Attach a write-ahead log (engine construction/recovery paths).
    pub(crate) fn attach_wal(&mut self, wal: crate::durable::SessionWal) {
        match self {
            AnySession::Single(s) => s.attach_wal(wal),
            AnySession::Sharded(s) => s.attach_wal(wal),
        }
    }

    /// Write-ahead log counters (`None` without durability).
    pub fn wal_stats(&self) -> Option<crate::durable::WalStats> {
        match self {
            AnySession::Single(s) => s.wal_stats(),
            AnySession::Sharded(s) => s.wal_stats(),
        }
    }

    /// The error that degraded the log, if any.
    pub fn wal_error(&self) -> Option<String> {
        match self {
            AnySession::Single(s) => s.wal_error(),
            AnySession::Sharded(s) => s.wal_error(),
        }
    }

    /// Force the epoch counter and republish — recovery's final step.
    pub(crate) fn force_epoch(&mut self, epoch: u64) {
        match self {
            AnySession::Single(s) => s.force_epoch(epoch),
            AnySession::Sharded(s) => s.force_epoch(epoch),
        }
    }

    /// Install a checkpoint of the committed state right now (resume).
    pub(crate) fn checkpoint_now(&mut self) {
        match self {
            AnySession::Single(s) => s.checkpoint_now(),
            AnySession::Sharded(s) => s.checkpoint_now(),
        }
    }

    /// Timestamp for a caller-recorded span (recovery envelope).
    pub(crate) fn trace_start(&self) -> u64 {
        match self {
            AnySession::Single(s) => s.trace_start(),
            AnySession::Sharded(s) => s.trace_start(),
        }
    }

    /// Record a caller-timed master-lane span on the session tracer.
    pub(crate) fn trace_span(&mut self, phase: crate::obs::Phase, t0: u64, items: u64) {
        match self {
            AnySession::Single(s) => s.trace_span(phase, t0, items),
            AnySession::Sharded(s) => s.trace_span(phase, t0, items),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DdmEngine;

    #[test]
    fn any_session_dispatches_by_builder_shards() {
        let span = Interval::new(0.0, 100.0);
        let single = DdmEngine::builder().threads(1).build().any_session(1, span);
        assert!(matches!(single, AnySession::Single(_)));
        assert_eq!(single.shards(), 1);
        assert!(single.shard_stats().is_none());
        assert!(single.imbalance().is_none());

        let sharded = DdmEngine::builder()
            .threads(2)
            .shards(4)
            .build()
            .any_session(2, span);
        assert!(matches!(sharded, AnySession::Sharded(_)));
        assert_eq!(sharded.shards(), 4);
        assert_eq!(sharded.shard_stats().unwrap().len(), 4);
    }

    #[test]
    fn any_session_paths_agree_on_the_same_script() {
        let span = Interval::new(0.0, 100.0);
        let mut sessions = vec![
            DdmEngine::builder().threads(2).build().any_session(1, span),
            DdmEngine::builder()
                .threads(2)
                .shards(3)
                .parallel_cutoff(1)
                .build()
                .any_session(1, span),
        ];
        let mut rng = crate::prng::Rng::new(0xA5E);
        for _ in 0..5 {
            for _ in 0..40 {
                let key = rng.below(20) as u32;
                let lo = rng.uniform(0.0, 90.0);
                let iv = Interval::new(lo, lo + rng.uniform(1.0, 45.0));
                let sub_side = rng.chance(0.5);
                for s in &mut sessions {
                    if sub_side {
                        s.upsert_subscription(key, &[iv]);
                    } else {
                        s.upsert_update(key, &[iv]);
                    }
                }
            }
            let diffs: Vec<MatchDiff> = sessions.iter_mut().map(|s| s.commit()).collect();
            assert_eq!(diffs[0], diffs[1]);
            assert_eq!(sessions[0].pairs(), sessions[1].pairs());
            assert_eq!(sessions[0].n_pairs(), sessions[1].n_pairs());
            let (a, b) = (sessions[0].snapshot(), sessions[1].snapshot());
            assert_eq!(a.epoch(), b.epoch(), "snapshot epochs diverged");
            assert_eq!(a.pairs(), b.pairs(), "snapshot pair sets diverged");
            assert_eq!(a.pairs(), sessions[0].pairs(), "snapshot != live reads");
        }
    }
}

//! Spatial partitioning of the routing space into shard stripes.
//!
//! A [`SpacePartitioner`] splits one chosen dimension of the routing
//! space into `shards` contiguous half-open stripes and routes every
//! region to the (inclusive) range of stripes its split-dimension
//! extent overlaps. Regions wider than a stripe are **replicated**
//! into every stripe they touch — the merge layer
//! ([`ShardedSession`](super::ShardedSession) /
//! [`ShardedMatcher`](super::ShardedMatcher)) owns deduplication.
//!
//! Two cut constructions:
//!
//! * [`uniform`](SpacePartitioner::uniform) — equal-width stripes over
//!   a known span (the HLA routing-space extent, a workload's bounds);
//! * [`balanced`](SpacePartitioner::balanced) — sample-based quantile
//!   cuts: given a sample of region positions on the split dimension,
//!   each stripe receives roughly the same number of sampled
//!   positions, which keeps skewed (hotspot) workloads from
//!   serializing on one hot shard.

use crate::core::interval::Interval;

/// Routes regions to the stripes of one split dimension.
///
/// Stripe `i` covers `[cuts[i-1], cuts[i])`, with stripe `0` open
/// below and the last stripe open above — every point of the real
/// line belongs to exactly one stripe, so routing never drops a
/// region no matter how the span estimate relates to the data.
#[derive(Debug, Clone, PartialEq)]
pub struct SpacePartitioner {
    split_dim: usize,
    /// Interior cut points, non-decreasing; `shards = cuts.len() + 1`.
    cuts: Vec<f64>,
}

impl SpacePartitioner {
    /// The trivial single-stripe partitioner (everything routes to
    /// shard 0).
    pub fn single(split_dim: usize) -> Self {
        Self {
            split_dim,
            cuts: Vec::new(),
        }
    }

    /// Equal-width stripes over `span` on dimension `split_dim`.
    pub fn uniform(shards: usize, split_dim: usize, span: Interval) -> Self {
        assert!(shards >= 1, "need at least one shard");
        let w = span.len() / shards as f64;
        let cuts = (1..shards).map(|i| span.lo + w * i as f64).collect();
        Self { split_dim, cuts }
    }

    /// Rebuild a partitioner from explicit interior cut points (e.g. a
    /// topology snapshot received over the wire — see
    /// [`net`](crate::net)). Cuts must be non-decreasing; the stripe
    /// count is `cuts.len() + 1`. Because routing is a pure function
    /// of the cut values, two partitioners built from bit-identical
    /// cuts route every region identically — the property the
    /// cross-process federation layer relies on.
    pub fn from_cuts(split_dim: usize, cuts: Vec<f64>) -> Self {
        assert!(
            cuts.windows(2).all(|w| w[0] <= w[1]),
            "cuts must be non-decreasing"
        );
        Self { split_dim, cuts }
    }

    /// Sample-based balanced stripes: cut at the `shards`-quantiles of
    /// `sample` (region positions on the split dimension), so each
    /// stripe holds roughly the same number of sampled positions.
    /// Duplicate quantiles (heavy point masses) are kept, degenerating
    /// to empty stripes rather than changing the shard count.
    pub fn balanced(shards: usize, split_dim: usize, sample: &[f64]) -> Self {
        assert!(shards >= 1, "need at least one shard");
        if shards == 1 || sample.is_empty() {
            return Self::single(split_dim);
        }
        let mut pts: Vec<f64> = sample.iter().copied().filter(|x| x.is_finite()).collect();
        if pts.is_empty() {
            return Self::single(split_dim);
        }
        pts.sort_unstable_by(f64::total_cmp);
        let cuts = (1..shards)
            .map(|i| pts[(i * pts.len() / shards).min(pts.len() - 1)])
            .collect();
        Self { split_dim, cuts }
    }

    /// Number of stripes.
    pub fn shards(&self) -> usize {
        self.cuts.len() + 1
    }

    /// The dimension this partitioner splits on.
    pub fn split_dim(&self) -> usize {
        self.split_dim
    }

    /// The interior cut points (ascending; `shards() - 1` of them).
    pub fn cuts(&self) -> &[f64] {
        &self.cuts
    }

    /// The stripe containing point `x`.
    pub fn shard_of(&self, x: f64) -> usize {
        self.cuts.partition_point(|&c| c <= x)
    }

    /// Inclusive stripe range `(first, last)` overlapped by the
    /// half-open interval `iv` on the split dimension. Empty intervals
    /// route to the single stripe containing their point.
    pub fn route(&self, iv: Interval) -> (usize, usize) {
        let first = self.cuts.partition_point(|&c| c <= iv.lo);
        let last = self.cuts.partition_point(|&c| c < iv.hi);
        (first, last.max(first))
    }

    /// Route a full rectangle (convenience: projects onto the split
    /// dimension).
    pub fn route_rect(&self, rect: &[Interval]) -> (usize, usize) {
        self.route(rect[self.split_dim])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_cuts_and_point_routing() {
        let p = SpacePartitioner::uniform(4, 0, Interval::new(0.0, 100.0));
        assert_eq!(p.shards(), 4);
        assert_eq!(p.cuts(), &[25.0, 50.0, 75.0]);
        assert_eq!(p.shard_of(0.0), 0);
        assert_eq!(p.shard_of(24.999), 0);
        assert_eq!(p.shard_of(25.0), 1, "cut points belong to the upper stripe");
        assert_eq!(p.shard_of(99.0), 3);
        // Out-of-span points still route (open outer stripes).
        assert_eq!(p.shard_of(-5.0), 0);
        assert_eq!(p.shard_of(1e9), 3);
    }

    #[test]
    fn interval_routing_covers_exactly_the_overlapped_stripes() {
        let p = SpacePartitioner::uniform(4, 0, Interval::new(0.0, 100.0));
        assert_eq!(p.route(Interval::new(0.0, 10.0)), (0, 0));
        assert_eq!(p.route(Interval::new(10.0, 30.0)), (0, 1));
        assert_eq!(p.route(Interval::new(0.0, 100.0)), (0, 3), "full-span region hits all");
        // Half-open: an interval ending exactly at a cut does NOT enter
        // the upper stripe; one starting at a cut does not touch the
        // lower one.
        assert_eq!(p.route(Interval::new(10.0, 25.0)), (0, 0));
        assert_eq!(p.route(Interval::new(25.0, 30.0)), (1, 1));
        // Empty interval at a cut point routes to one stripe.
        assert_eq!(p.route(Interval::new(25.0, 25.0)), (1, 1));
    }

    #[test]
    fn single_and_one_shard_route_everything_to_zero() {
        for p in [
            SpacePartitioner::single(0),
            SpacePartitioner::uniform(1, 0, Interval::new(0.0, 10.0)),
        ] {
            assert_eq!(p.shards(), 1);
            assert_eq!(p.route(Interval::new(-1e9, 1e9)), (0, 0));
        }
    }

    #[test]
    fn balanced_cuts_follow_the_sample_density() {
        // 90% of the mass in [0, 10), 10% in [10, 100): quantile cuts
        // land inside the dense prefix.
        let mut sample = Vec::new();
        for i in 0..90 {
            sample.push(i as f64 * 10.0 / 90.0);
        }
        for i in 0..10 {
            sample.push(10.0 + i as f64 * 9.0);
        }
        let p = SpacePartitioner::balanced(4, 0, &sample);
        assert_eq!(p.shards(), 4);
        assert!(p.cuts()[0] < 10.0 && p.cuts()[1] < 10.0, "cuts {:?}", p.cuts());
        // The uniform alternative puts every cut outside the hotspot.
        let u = SpacePartitioner::uniform(4, 0, Interval::new(0.0, 100.0));
        assert!(u.cuts().iter().all(|&c| c >= 10.0));
    }

    #[test]
    fn from_cuts_round_trips_routing() {
        let u = SpacePartitioner::uniform(4, 1, Interval::new(0.0, 100.0));
        let r = SpacePartitioner::from_cuts(u.split_dim(), u.cuts().to_vec());
        assert_eq!(r, u);
        for iv in [
            Interval::new(0.0, 10.0),
            Interval::new(10.0, 30.0),
            Interval::new(25.0, 25.0),
            Interval::new(-5.0, 500.0),
        ] {
            assert_eq!(r.route(iv), u.route(iv));
        }
    }

    #[test]
    fn balanced_keeps_shard_count_under_degenerate_samples() {
        let p = SpacePartitioner::balanced(5, 2, &[7.0; 100]);
        assert_eq!(p.shards(), 5);
        assert_eq!(p.split_dim(), 2);
        let (a, b) = p.route(Interval::new(0.0, 100.0));
        assert_eq!((a, b), (0, 4), "wide region still spans all stripes");
        assert!(SpacePartitioner::balanced(3, 0, &[]).shards() == 1);
    }
}

//! Sharded epoch sessions: one inner [`DdmSession`] per spatial
//! stripe, committed in parallel, with per-shard diffs merged into one
//! globally deduplicated [`MatchDiff`].
//!
//! [`ShardedSession`] mirrors the [`DdmSession`] staging API (upsert /
//! remove / [`commit`](ShardedSession::commit)) and adds a routing
//! layer in front of it: every staged op is forwarded at apply time to
//! the shards whose stripes the region's split-dimension extent
//! overlaps ([`SpacePartitioner::route`]), with regions that moved
//! across a stripe boundary re-routed (removed from the shards they
//! left, upserted into the ones they entered). Commit then closes the
//! epoch on every shard **in parallel on the engine's
//! [`exec`](crate::exec) pool** — each inner session runs serially
//! (`nthreads = 1`), so the fan-out region is the only pool user and
//! nested parallel regions never happen.
//!
//! ## Diff merging and boundary replication
//!
//! A region wider than one stripe lives in several shards, so a pair
//! may be live in several shards at once. The merge layer keeps one
//! reference count per pair — the number of shards currently holding
//! it — and folds every shard's epoch diff through it: a pair is
//! *globally added* only on a `0 → >0` transition and *globally
//! removed* only on a `>0 → 0` transition. This gives exactly the
//! required semantics:
//!
//! * a pair discovered by `k > 1` shards in one epoch (both regions
//!   straddle the boundary) is reported **once**;
//! * a region crossing a boundary while still intersecting its partner
//!   nets a shard-local remove against a shard-local add and is
//!   reported **not at all**;
//! * a pair leaving every shard is reported removed exactly once.
//!
//! ## Wait-free reads
//!
//! Every publish point (flush / commit) also merges the shards'
//! per-epoch snapshots into one cached global
//! [`EpochSnapshot`](crate::session::EpochSnapshot). All read
//! accessors (`pairs`, `n_pairs`, `updates_of`, `subscriptions_of`,
//! `contains_pair`) answer from that cache — a pure reader never takes
//! a shard lock, never routes staged ops, and never observes a flush
//! side effect; [`snapshot`](ShardedSession::snapshot) hands the same
//! immutable view out for readers that outlive the next commit.

// xlint: allow-file(hot-lock): the per-shard Mutex is the design —
// each inner session is locked by exactly one worker during the
// fan-out commit (shards are the partition unit), and every other
// access is from &mut self or read-side sweeps outside the hot loop.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::core::interval::Interval;
use crate::core::sink::{pack_pair, unpack_pair, PairVec};
use crate::core::{Regions1D, RegionsNd};
use crate::exec::ThreadPool;
use crate::session::{DdmSession, EpochSnapshot, IngestReceiver, MatchDiff, SessionParams, Side};

use super::partition::SpacePartitioner;
use super::ShardStrategy;

/// Poison-recovering lock: a shard whose session panicked mid-epoch
/// still yields its state (the panic already propagated through the
/// pool's fan-in; the data itself is a plain session).
fn lock_ok(cell: &Mutex<DdmSession>) -> MutexGuard<'_, DdmSession> {
    cell.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Poison-recovering `get_mut` for the serial (uncontended) paths.
fn get_mut_ok(cell: &mut Mutex<DdmSession>) -> &mut DdmSession {
    cell.get_mut().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Per-shard load snapshot (the coordinator's imbalance gauge and the
/// `abl_shard` bench read these).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Stripe index.
    pub shard: usize,
    /// Live subscription regions routed into this shard (replicas
    /// count once per shard they live in).
    pub subscriptions: usize,
    /// Live update regions routed into this shard.
    pub updates: usize,
    /// Pairs retained by this shard's inner session.
    pub retained_pairs: usize,
    /// Ops forwarded to this shard during the last committed epoch.
    pub last_ops: usize,
    /// Shard-local diff churn (|added| + |removed|) of the last epoch.
    pub last_churn: usize,
    /// Wall time of this shard's inner commit in the last epoch,
    /// nanoseconds ([`crate::obs::clock::now_ns`] domain; measured on
    /// every commit, traced or not). `0` before the first commit. The
    /// timing half of the imbalance story:
    /// [`ShardedSession::commit_time_imbalance_of`] reads it.
    pub last_commit_ns: u64,
}

/// A spatially sharded [`DdmSession`]: staged ops are routed to
/// stripe-owning inner sessions, epochs commit shard-parallel, and the
/// merged [`MatchDiff`] is globally deduplicated. See the
/// [module docs](self) for the routing and merge rules.
///
/// Constructed through the engine
/// ([`DdmEngine::sharded_session`](crate::engine::DdmEngine::sharded_session)
/// with a span, or
/// [`sharded_session_with`](crate::engine::DdmEngine::sharded_session_with)
/// with an explicit [`SpacePartitioner`]).
pub struct ShardedSession {
    d: usize,
    part: SpacePartitioner,
    /// Balanced strategy: re-derive quantile cuts from the first
    /// non-empty staged batch before anything is routed.
    balance_pending: bool,
    pool: Arc<ThreadPool>,
    nthreads: usize,
    params: SessionParams,
    inner: Vec<Mutex<DdmSession>>,
    /// Current stripe range of every live region (applied state).
    sub_homes: HashMap<u32, (usize, usize)>,
    upd_homes: HashMap<u32, (usize, usize)>,
    /// Staged ops, coalesced last-write-wins (same contract as
    /// [`DdmSession`]): key → `Some(rect)` upsert / `None` remove.
    pending_subs: BTreeMap<u32, Option<Vec<Interval>>>,
    pending_upds: BTreeMap<u32, Option<Vec<Interval>>>,
    /// Global pair → number of shards currently holding it.
    pair_refs: HashMap<u64, u32>,
    /// Cached merged read snapshot, rebuilt at every publish point
    /// (flush / commit) from the shards' own snapshots — the wait-free
    /// surface every read accessor answers from (no shard locks).
    snap: EpochSnapshot,
    epoch: u64,
    /// Ops forwarded per shard since the last commit.
    ops_since_commit: Vec<usize>,
    last_epoch_ops: Vec<usize>,
    last_epoch_churn: Vec<usize>,
    /// Wall time of each shard's inner commit in the last epoch
    /// (measured on every commit; feeds [`ShardStats::last_commit_ns`]
    /// and the commit-time imbalance gauge).
    last_epoch_commit_ns: Vec<u64>,
    /// Shard-level span timeline ([`SessionParams::trace`]): one
    /// [`Phase::ShardCommit`](crate::obs::Phase::ShardCommit) span per
    /// shard per epoch on lane = shard id, the inner sessions' phase
    /// spans remapped onto the same lane, plus master-lane merge and
    /// commit-envelope spans.
    tracer: crate::obs::Tracer,
    /// Crash-consistency at the *sharded* level: one log for the whole
    /// session (inner shard sessions stay WAL-free — replay re-routes
    /// through the same partitioner), appended at stage time, marked
    /// durable after each merged publish.
    wal: Option<crate::durable::SessionWal>,
}

impl ShardedSession {
    /// A fresh `d`-dimensional sharded session. Inner sessions run
    /// serially (`nthreads = 1` each); `nthreads` bounds the *cross-
    /// shard* fan-out on `pool`.
    pub fn new(
        d: usize,
        part: SpacePartitioner,
        strategy: ShardStrategy,
        pool: Arc<ThreadPool>,
        nthreads: usize,
        params: SessionParams,
    ) -> Self {
        assert!(d >= 1, "sessions need at least one dimension");
        let split = part.split_dim();
        assert!(split < d, "split dimension {split} out of range for d={d}");
        let shards = part.shards();
        let inner = (0..shards)
            .map(|_| Mutex::new(DdmSession::new(d, Arc::clone(&pool), 1, params)))
            .collect();
        Self {
            d,
            balance_pending: strategy == ShardStrategy::Balanced && shards > 1,
            part,
            pool,
            nthreads: nthreads.max(1),
            params,
            inner,
            sub_homes: HashMap::new(),
            upd_homes: HashMap::new(),
            pending_subs: BTreeMap::new(),
            pending_upds: BTreeMap::new(),
            pair_refs: HashMap::new(),
            snap: EpochSnapshot::default(),
            epoch: 0,
            ops_since_commit: vec![0; shards],
            last_epoch_ops: vec![0; shards],
            last_epoch_churn: vec![0; shards],
            last_epoch_commit_ns: vec![0; shards],
            tracer: crate::obs::Tracer::new(params.trace),
            wal: None,
        }
    }

    /// Attach a write-ahead log (engine construction/recovery paths;
    /// same contract as
    /// [`DdmSession::attach_wal`](crate::session::DdmSession)).
    pub(crate) fn attach_wal(&mut self, wal: crate::durable::SessionWal) {
        self.wal = Some(wal);
    }

    /// Write-ahead log counters, if durability is attached.
    pub fn wal_stats(&self) -> Option<crate::durable::WalStats> {
        self.wal.as_ref().map(crate::durable::SessionWal::stats)
    }

    /// The error that degraded the log, if any.
    pub fn wal_error(&self) -> Option<String> {
        self.wal
            .as_ref()
            .and_then(|w| w.last_error().map(str::to_string))
    }

    /// Force the epoch counter and republish the merged snapshot under
    /// it — recovery's final step (see
    /// [`DdmSession::force_epoch`](crate::session::DdmSession)).
    pub(crate) fn force_epoch(&mut self, epoch: u64) {
        let snaps: Vec<EpochSnapshot> = self
            .inner
            .iter()
            .map(|cell| lock_ok(cell).snapshot())
            .collect();
        self.epoch = epoch;
        self.publish_merged(&snaps);
    }

    /// Install a checkpoint of the current committed state right now
    /// (the resume path truncates the recovered-from log with this).
    pub(crate) fn checkpoint_now(&mut self) {
        if let Some(wal) = self.wal.as_mut() {
            wal.checkpoint(&self.snap);
        }
    }

    /// Timestamp for a caller-recorded span (recovery envelope).
    pub(crate) fn trace_start(&self) -> u64 {
        self.tracer.start()
    }

    /// Record a caller-timed master-lane span on this session's tracer.
    pub(crate) fn trace_span(&mut self, phase: crate::obs::Phase, t0: u64, items: u64) {
        self.tracer.span(phase, t0, items);
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of shards (stripes).
    pub fn shards(&self) -> usize {
        self.inner.len()
    }

    /// Per-shard scratch capacity snapshots (each inner session owns
    /// its own [`MatchScratch`](crate::core::scratch::MatchScratch),
    /// so shard-parallel commits reuse buffers without sharing or
    /// locking across shards) — for allocation-free assertions.
    pub fn scratch_stats(&self) -> Vec<crate::core::ScratchStats> {
        self.inner
            .iter()
            .map(|cell| lock_ok(cell).scratch_stats())
            .collect()
    }

    /// The active partitioner (balanced sessions: quantile cuts after
    /// the first apply).
    pub fn partitioner(&self) -> &SpacePartitioner {
        &self.part
    }

    /// Number of committed epochs.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Staged (coalesced) region ops not yet routed to the shards.
    pub fn pending_ops(&self) -> usize {
        self.pending_subs.len() + self.pending_upds.len()
    }

    /// Live subscription regions (applied state; replicas count once).
    pub fn n_subscriptions(&self) -> usize {
        self.sub_homes.len()
    }

    /// Live update regions (applied state; replicas count once).
    pub fn n_updates(&self) -> usize {
        self.upd_homes.len()
    }

    /// Globally intersecting pairs: O(1) from the cached merged
    /// snapshot (rebuilt at every flush / commit, so it always agrees
    /// with [`pairs`](Self::pairs) and with the unsharded session
    /// behind [`AnySession`](super::AnySession)). No shard locks.
    pub fn n_pairs(&self) -> usize {
        self.snap.n_pairs()
    }

    // ---- staging -----------------------------------------------------------

    /// Stage an insert-or-replace of subscription region `key`.
    pub fn upsert_subscription(&mut self, key: u32, rect: &[Interval]) {
        assert_eq!(rect.len(), self.d, "rect dimension != session dimension {}", self.d);
        if let Some(wal) = self.wal.as_mut() {
            wal.log_op(true, key, Some(rect));
        }
        self.pending_subs.insert(key, Some(rect.to_vec()));
        self.auto_apply();
    }

    /// Stage an insert-or-replace of update region `key`.
    pub fn upsert_update(&mut self, key: u32, rect: &[Interval]) {
        assert_eq!(rect.len(), self.d, "rect dimension != session dimension {}", self.d);
        if let Some(wal) = self.wal.as_mut() {
            wal.log_op(false, key, Some(rect));
        }
        self.pending_upds.insert(key, Some(rect.to_vec()));
        self.auto_apply();
    }

    /// Stage removal of subscription region `key` (no-op if absent).
    pub fn remove_subscription(&mut self, key: u32) {
        if let Some(wal) = self.wal.as_mut() {
            wal.log_op(true, key, None);
        }
        self.pending_subs.insert(key, None);
        self.auto_apply();
    }

    /// Stage removal of update region `key` (no-op if absent).
    pub fn remove_update(&mut self, key: u32) {
        if let Some(wal) = self.wal.as_mut() {
            wal.log_op(false, key, None);
        }
        self.pending_upds.insert(key, None);
        self.auto_apply();
    }

    /// Honor [`SessionParams::batch_threshold`] like the unsharded
    /// session does: once that many distinct regions are staged,
    /// route and apply early (the epoch stays open, so the committed
    /// diff is unchanged) — staged memory and commit latency stay
    /// bounded under heavy churn.
    fn auto_apply(&mut self) {
        let threshold = self.params.batch_threshold;
        if threshold > 0 && self.pending_ops() >= threshold {
            self.flush();
        }
    }

    /// Stage a whole 1-D workload keyed by dense index.
    pub fn load_dense_1d(&mut self, subs: &Regions1D, upds: &Regions1D) {
        assert_eq!(self.d, 1, "load_dense_1d on a {}-d session", self.d);
        for i in 0..subs.len() {
            self.upsert_subscription(i as u32, &[subs.get(i)]);
        }
        for j in 0..upds.len() {
            self.upsert_update(j as u32, &[upds.get(j)]);
        }
    }

    /// Stage a whole d-dimensional workload keyed by dense index.
    pub fn load_dense(&mut self, subs: &RegionsNd, upds: &RegionsNd) {
        assert_eq!(subs.d(), self.d, "subscription dimension mismatch");
        assert_eq!(upds.d(), self.d, "update dimension mismatch");
        for i in 0..subs.len() {
            self.upsert_subscription(i as u32, &subs.get(i));
        }
        for j in 0..upds.len() {
            self.upsert_update(j as u32, &upds.get(j));
        }
    }

    // ---- routing -----------------------------------------------------------

    /// Balanced strategy, first non-empty batch: replace the fallback
    /// cuts with quantiles of the staged regions' split-dim midpoints.
    fn maybe_balance(&mut self) {
        if !self.balance_pending {
            return;
        }
        let k = self.part.split_dim();
        let mut sample: Vec<f64> = Vec::new();
        for op in self.pending_subs.values().chain(self.pending_upds.values()) {
            if let Some(rect) = op {
                sample.push(0.5 * (rect[k].lo + rect[k].hi));
            }
        }
        if sample.is_empty() {
            return; // removal-only batch: keep waiting for real data
        }
        let rebuilt = SpacePartitioner::balanced(self.inner.len(), k, &sample);
        debug_assert_eq!(rebuilt.shards(), self.inner.len());
        self.part = rebuilt;
        self.balance_pending = false;
    }

    /// Forward every staged op to its owning shards, re-routing
    /// regions whose extent crossed a stripe boundary: shards the
    /// region left get a remove, shards it now overlaps get the
    /// upsert. Inner sessions coalesce per key, so repeated routing
    /// within one epoch stays cheap.
    fn route_pending(&mut self) {
        if self.pending_subs.is_empty() && self.pending_upds.is_empty() {
            return;
        }
        self.maybe_balance();
        let sub_ops = std::mem::take(&mut self.pending_subs);
        let upd_ops = std::mem::take(&mut self.pending_upds);
        if let Some(wal) = self.wal.as_mut() {
            // Shadow the committed region tables for checkpoints (the
            // routed batch is exactly what this epoch applies).
            wal.apply_committed(&sub_ops, &upd_ops);
        }
        for (key, op) in sub_ops {
            route_one(
                &self.part,
                &mut self.inner,
                &mut self.sub_homes,
                &mut self.ops_since_commit,
                key,
                op,
                |sess, key, rect| sess.upsert_subscription(key, rect),
                |sess, key| sess.remove_subscription(key),
            );
        }
        for (key, op) in upd_ops {
            route_one(
                &self.part,
                &mut self.inner,
                &mut self.upd_homes,
                &mut self.ops_since_commit,
                key,
                op,
                |sess, key, rect| sess.upsert_update(key, rect),
                |sess, key| sess.remove_update(key),
            );
        }
    }

    // ---- committing --------------------------------------------------------

    /// Route and apply all staged ops **without closing the epoch**:
    /// reads see current state, the per-shard diff accumulators stay
    /// queued for the next [`commit`](Self::commit). No-op when
    /// nothing is staged (routing only happens here and in `commit`,
    /// so empty pending maps imply the inner sessions are drained too
    /// — the read hot path never pays a fan-out).
    pub fn flush(&mut self) {
        if self.pending_subs.is_empty() && self.pending_upds.is_empty() {
            return;
        }
        self.route_pending();
        let snaps = self.fan(|sess| {
            sess.flush();
            sess.snapshot()
        });
        self.publish_merged(&snaps);
    }

    /// Route and apply all staged ops, close the epoch on every shard
    /// in parallel, and merge the per-shard diffs into one globally
    /// deduplicated [`MatchDiff`].
    pub fn commit(&mut self) -> MatchDiff {
        let t_commit = self.tracer.start();
        // Write-ahead point: this epoch's op records hit the disk
        // before any shard applies or the merged snapshot publishes.
        if let Some(wal) = self.wal.as_mut() {
            wal.flush_ops(&mut self.tracer);
        }
        self.route_pending();
        // Time every inner commit — two clock reads per shard, cheap
        // enough to keep on even untraced, so the commit-time
        // imbalance gauge always sees real durations — and, when
        // tracing, carry each shard's drained phase spans back with
        // its diff.
        let traced = self.tracer.is_enabled();
        let results = self.fan(|sess| {
            let t0 = crate::obs::clock::now_ns();
            let diff = sess.commit();
            let t1 = crate::obs::clock::now_ns();
            let spans = if traced { sess.drain_trace() } else { Vec::new() };
            (diff, t0, t1, spans, sess.snapshot())
        });
        self.epoch += 1;
        self.last_epoch_ops = std::mem::replace(
            &mut self.ops_since_commit,
            vec![0; self.inner.len()],
        );

        // Fold every shard's diff through the global refcounts; only
        // 0 ↔ >0 transitions surface.
        let t_merge = self.tracer.start();
        let mut delta: HashMap<u64, i32> = HashMap::new();
        let mut snaps: Vec<EpochSnapshot> = Vec::with_capacity(self.inner.len());
        for (i, (diff, t0, t1, spans, snap)) in results.into_iter().enumerate() {
            snaps.push(snap);
            self.last_epoch_churn[i] = diff.churn();
            self.last_epoch_commit_ns[i] = t1.saturating_sub(t0);
            if traced {
                // The inner sessions' phase spans were stamped on
                // *their* master lane, which means nothing outside
                // their session — remap them onto lane = shard id so
                // the trace shows each shard's sub-phases under its
                // own ShardCommit envelope.
                let lane = i as u16;
                for r in spans {
                    if let Some(p) = crate::obs::Phase::from_id(r.phase) {
                        self.tracer.span_at(p, lane, r.t0_ns, r.t1_ns, r.items);
                    }
                }
                self.tracer.span_at(
                    crate::obs::Phase::ShardCommit,
                    lane,
                    t0,
                    t1,
                    diff.churn() as u64,
                );
            }
            for &(s, u) in &diff.added {
                *delta.entry(pack_pair(s, u)).or_insert(0) += 1;
            }
            for &(s, u) in &diff.removed {
                *delta.entry(pack_pair(s, u)).or_insert(0) -= 1;
            }
        }
        let mut added: PairVec = Vec::new();
        let mut removed: PairVec = Vec::new();
        for (pair, dv) in delta {
            if dv == 0 {
                continue;
            }
            let old = self.pair_refs.get(&pair).copied().unwrap_or(0) as i64;
            let new = old + dv as i64;
            debug_assert!(new >= 0, "pair refcount went negative");
            if old == 0 && new > 0 {
                added.push(unpack_pair(pair));
            } else if old > 0 && new <= 0 {
                removed.push(unpack_pair(pair));
            }
            if new <= 0 {
                self.pair_refs.remove(&pair);
            } else {
                self.pair_refs.insert(pair, new as u32);
            }
        }
        added.sort_unstable();
        removed.sort_unstable();
        let churn = (added.len() + removed.len()) as u64;
        self.tracer.span(crate::obs::Phase::DiffMerge, t_merge, churn);
        self.publish_merged(&snaps);
        if let Some(wal) = self.wal.as_mut() {
            wal.on_commit(&self.snap, &mut self.tracer);
        }
        self.tracer.span(crate::obs::Phase::Commit, t_commit, churn);
        MatchDiff {
            epoch: self.epoch,
            added,
            removed,
        }
    }

    /// Merge the shards' per-epoch snapshots into one global view and
    /// RCU-swap the read cache (same publish spans as the unsharded
    /// session: `snapshot_swap` sized by the new pair count,
    /// `reader_pin` counting handles still pinning the old payload).
    fn publish_merged(&mut self, parts: &[EpochSnapshot]) {
        let t_swap = self.tracer.start();
        let merged = EpochSnapshot::merge(
            self.epoch,
            parts,
            self.sub_homes.len(),
            self.upd_homes.len(),
        );
        let pairs = merged.n_pairs() as u64;
        let pinned = (self.snap.readers() - 1) as u64;
        self.snap = merged;
        self.tracer.span(crate::obs::Phase::SnapshotSwap, t_swap, pairs);
        let t_pin = self.tracer.start();
        self.tracer.span(crate::obs::Phase::ReaderPin, t_pin, pinned);
    }

    /// The current merged read snapshot: a wait-free, refcounted view
    /// of the applied state as of the last flush / commit. O(1); the
    /// returned handle stays valid (and bit-identical) across later
    /// commits and after the session is dropped.
    pub fn snapshot(&self) -> EpochSnapshot {
        self.snap.clone()
    }

    /// Drain a bounded ingest queue (see
    /// [`ingest_queue`](crate::session::ingest_queue)) into the
    /// staging maps: every queued op becomes an ordinary staged
    /// upsert / remove (LWW-coalesced, `batch_threshold` honored).
    /// Returns the drained count; traced sessions fold the batch's
    /// backlog dwell into one
    /// [`BacklogWait`](crate::obs::Phase::BacklogWait) span.
    pub fn drain_ingest(&mut self, rx: &IngestReceiver) -> usize {
        let (drained, oldest) = rx.drain(|op| match (op.side, op.op) {
            (Side::Subscription, Some(rect)) => self.upsert_subscription(op.key, &rect),
            (Side::Subscription, None) => self.remove_subscription(op.key),
            (Side::Update, Some(rect)) => self.upsert_update(op.key, &rect),
            (Side::Update, None) => self.remove_update(op.key),
        });
        if drained > 0 && self.tracer.is_enabled() {
            let now = crate::obs::clock::now_ns();
            self.tracer.span_at(
                crate::obs::Phase::BacklogWait,
                crate::obs::trace::MASTER_WORKER,
                oldest.min(now),
                now,
                drained as u64,
            );
        }
        drained
    }

    /// The parameters every inner session was built with.
    pub fn params(&self) -> SessionParams {
        self.params
    }

    /// Run `f` on every inner session — across shards on the worker
    /// pool when the batch is big enough, inline otherwise. Inner
    /// sessions are serial, so the fan-out region is the pool's only
    /// user (no nested parallel regions).
    fn fan<T, F>(&mut self, f: F) -> Vec<T>
    where
        T: Default + Send,
        F: Fn(&mut DdmSession) -> T + Sync,
    {
        // Fan out whenever the pool has workers and the batch is big
        // enough — also for a single shard, so the work lands in a
        // pool region and the bench cost log sees it.
        let shards = self.inner.len();
        let staged: usize = self.ops_since_commit.iter().sum();
        let par = self.nthreads > 1 && staged >= self.params.parallel_cutoff;
        if !par {
            return self
                .inner
                .iter_mut()
                .map(|cell| f(get_mut_ok(cell)))
                .collect();
        }
        let inner = &self.inner;
        self.pool.fan_map(self.nthreads.min(shards), shards, |i| {
            let mut guard = lock_ok(&inner[i]);
            f(&mut *guard)
        })
    }

    // ---- queries over the retained state -----------------------------------
    //
    // All of these answer from the cached merged snapshot — the
    // applied state as of the last flush / commit (call `flush` first
    // to see staged ops). A pure reader takes no shard locks and
    // triggers no routing, ever.

    /// Every currently intersecting (subscription key, update key)
    /// pair, sorted, deduplicated across boundary replicas.
    pub fn pairs(&self) -> PairVec {
        self.snap.pairs()
    }

    /// Update keys currently intersecting subscription `key`, sorted,
    /// deduplicated across the shards the subscription lives in.
    pub fn updates_of(&self, sub_key: u32) -> Vec<u32> {
        self.snap.updates_of(sub_key)
    }

    /// Subscription keys currently intersecting update `key`, sorted,
    /// deduplicated across the shards the update lives in.
    pub fn subscriptions_of(&self, upd_key: u32) -> Vec<u32> {
        self.snap.subscriptions_of(upd_key)
    }

    /// Whether the pair currently intersects (in any shard).
    pub fn contains_pair(&self, sub_key: u32, upd_key: u32) -> bool {
        self.snap.contains_pair(sub_key, upd_key)
    }

    // ---- introspection ------------------------------------------------------

    /// Per-shard load snapshot (region counts, retained pairs, last
    /// epoch's routed ops and diff churn). One lock sweep; feed the
    /// result to [`imbalance_of`](Self::imbalance_of) to avoid
    /// re-reading the shards for the gauge.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.inner
            .iter()
            .enumerate()
            .map(|(i, cell)| {
                let sess = lock_ok(cell);
                ShardStats {
                    shard: i,
                    subscriptions: sess.region_count(Side::Subscription),
                    updates: sess.region_count(Side::Update),
                    retained_pairs: sess.retained_pair_count(),
                    last_ops: self.last_epoch_ops[i],
                    last_churn: self.last_epoch_churn[i],
                    last_commit_ns: self.last_epoch_commit_ns[i],
                }
            })
            .collect()
    }

    /// Load imbalance over a stats snapshot: max over shards of
    /// (regions in shard) divided by the mean — `1.0` is perfectly
    /// balanced, `stats.len()` is everything-on-one-shard; `1.0` when
    /// empty. Pure arithmetic: no shard locks are taken.
    pub fn imbalance_of(stats: &[ShardStats]) -> f64 {
        let loads: Vec<usize> = stats.iter().map(|s| s.subscriptions + s.updates).collect();
        let total: usize = loads.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / loads.len() as f64;
        loads.into_iter().max().unwrap_or(0) as f64 / mean
    }

    /// Load imbalance gauge over the current shard state (one lock
    /// sweep; callers that already hold a [`shard_stats`](Self::shard_stats)
    /// snapshot should use [`imbalance_of`](Self::imbalance_of)).
    pub fn imbalance(&self) -> f64 {
        Self::imbalance_of(&self.shard_stats())
    }

    /// Commit-**time** imbalance over a stats snapshot: max over
    /// shards of (last inner-commit wall time) divided by the mean —
    /// the measured counterpart of the region-count gauge
    /// [`imbalance_of`](Self::imbalance_of), answering "did the epoch
    /// actually parallelize?" rather than "is the data spread out?".
    /// `None` until a commit has run (all durations still zero). Pure
    /// arithmetic: no shard locks are taken.
    pub fn commit_time_imbalance_of(stats: &[ShardStats]) -> Option<f64> {
        let total: u64 = stats.iter().map(|s| s.last_commit_ns).sum();
        if total == 0 {
            return None;
        }
        let mean = total as f64 / stats.len() as f64;
        let max = stats.iter().map(|s| s.last_commit_ns).max().unwrap_or(0);
        Some(max as f64 / mean)
    }

    /// Whether this session is capturing shard-level phase spans.
    pub fn trace_enabled(&self) -> bool {
        self.tracer.is_enabled()
    }

    /// Take the spans recorded since the last drain (empty when built
    /// without [`SessionParams::trace`]): per-shard
    /// [`ShardCommit`](crate::obs::Phase::ShardCommit) envelopes and
    /// remapped inner phase spans on lane = shard id, merge and
    /// whole-commit spans on the master lane.
    pub fn drain_trace(&mut self) -> Vec<crate::obs::SpanRecord> {
        self.tracer.drain()
    }

    /// Spans lost to full trace buffers since construction.
    pub fn trace_dropped(&self) -> u64 {
        self.tracer.dropped()
    }
}

/// Route one coalesced op: diff the region's new stripe range against
/// its old one, remove from departed shards, upsert into current ones.
#[allow(clippy::too_many_arguments)]
fn route_one(
    part: &SpacePartitioner,
    inner: &mut [Mutex<DdmSession>],
    homes: &mut HashMap<u32, (usize, usize)>,
    ops: &mut [usize],
    key: u32,
    op: Option<Vec<Interval>>,
    upsert: impl Fn(&mut DdmSession, u32, &[Interval]),
    remove: impl Fn(&mut DdmSession, u32),
) {
    match op {
        Some(rect) => {
            let (a, b) = part.route_rect(&rect);
            if let Some(&(oa, ob)) = homes.get(&key) {
                for i in oa..=ob {
                    if i < a || i > b {
                        remove(get_mut_ok(&mut inner[i]), key);
                        ops[i] += 1;
                    }
                }
            }
            for i in a..=b {
                upsert(get_mut_ok(&mut inner[i]), key, &rect);
                ops[i] += 1;
            }
            homes.insert(key, (a, b));
        }
        None => {
            if let Some((oa, ob)) = homes.remove(&key) {
                for i in oa..=ob {
                    remove(get_mut_ok(&mut inner[i]), key);
                    ops[i] += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DdmEngine;
    use crate::prng::Rng;

    fn sharded(shards: usize, d: usize, span_hi: f64) -> ShardedSession {
        let part = SpacePartitioner::uniform(shards, 0, Interval::new(0.0, span_hi));
        DdmEngine::builder()
            .threads(2)
            .parallel_cutoff(1)
            .build()
            .sharded_session_with(d, part)
    }

    #[test]
    fn straddling_pair_is_reported_exactly_once() {
        // Both regions cross the single cut at 50: each lives in both
        // shards, the pair is live in both, the diff reports it once.
        let mut sess = sharded(2, 1, 100.0);
        sess.upsert_subscription(1, &[Interval::new(40.0, 60.0)]);
        sess.upsert_update(2, &[Interval::new(45.0, 55.0)]);
        let d = sess.commit();
        assert_eq!(d.added, vec![(1, 2)]);
        assert!(d.removed.is_empty());
        assert_eq!(sess.n_pairs(), 1);
        assert_eq!(sess.pairs(), vec![(1, 2)]);
        assert_eq!(sess.updates_of(1), vec![2]);
        assert_eq!(sess.subscriptions_of(2), vec![1]);
        assert!(sess.contains_pair(1, 2));

        // Removing the wide subscription reports the removal once.
        sess.remove_subscription(1);
        let d = sess.commit();
        assert_eq!(d.removed, vec![(1, 2)]);
        assert!(d.added.is_empty());
        assert_eq!(sess.n_pairs(), 0);
        assert!(sess.pairs().is_empty());
    }

    #[test]
    fn boundary_crossing_move_of_a_live_pair_is_silent() {
        // Update spans both stripes; the subscription hops from stripe
        // 0 to stripe 1 while never ceasing to intersect it. Shard 0
        // reports a remove, shard 1 an add — the merge nets to nothing.
        let mut sess = sharded(2, 1, 100.0);
        sess.upsert_subscription(7, &[Interval::new(10.0, 20.0)]);
        sess.upsert_update(9, &[Interval::new(0.0, 100.0)]);
        assert_eq!(sess.commit().added, vec![(7, 9)]);
        sess.upsert_subscription(7, &[Interval::new(70.0, 80.0)]);
        let d = sess.commit();
        assert!(d.is_empty(), "{d:?}");
        assert_eq!(sess.n_pairs(), 1);
        assert_eq!(sess.pairs(), vec![(7, 9)]);
    }

    #[test]
    fn rerouting_cleans_up_departed_shards() {
        let mut sess = sharded(4, 1, 100.0);
        sess.upsert_subscription(1, &[Interval::new(0.0, 100.0)]); // all 4 shards
        sess.upsert_update(2, &[Interval::new(80.0, 90.0)]); // shard 3
        assert_eq!(sess.commit().added, vec![(1, 2)]);
        // Shrink the subscription into stripe 0: it must leave shards
        // 1..=3 (losing the pair) and keep exactly one home.
        sess.upsert_subscription(1, &[Interval::new(5.0, 15.0)]);
        let d = sess.commit();
        assert_eq!(d.removed, vec![(1, 2)]);
        let stats = sess.shard_stats();
        assert_eq!(
            stats.iter().map(|s| s.subscriptions).collect::<Vec<_>>(),
            vec![1, 0, 0, 0]
        );
        assert_eq!(stats[3].updates, 1);
    }

    #[test]
    fn one_shard_degenerates_to_plain_session_behavior() {
        let mut sh = sharded(1, 1, 100.0);
        let mut un = DdmEngine::builder().threads(1).build().session(1);
        let mut rng = Rng::new(0x54A1);
        for _ in 0..6 {
            for _ in 0..40 {
                let key = rng.below(25) as u32;
                let lo = rng.uniform(0.0, 90.0);
                let iv = Interval::new(lo, lo + rng.uniform(0.5, 15.0));
                match rng.below(4) {
                    0 | 1 => {
                        sh.upsert_subscription(key, &[iv]);
                        un.upsert_subscription(key, &[iv]);
                    }
                    2 => {
                        sh.upsert_update(key, &[iv]);
                        un.upsert_update(key, &[iv]);
                    }
                    _ => {
                        sh.remove_subscription(key);
                        un.remove_subscription(key);
                    }
                }
            }
            assert_eq!(sh.commit(), un.commit());
            assert_eq!(sh.pairs(), un.pairs());
            assert_eq!(sh.n_pairs(), un.n_pairs());
        }
    }

    /// Random multi-shard churn with regions regularly wider than one
    /// stripe: merged sharded diffs == unsharded diffs, every epoch.
    #[test]
    fn sharded_and_unsharded_sessions_agree_under_wide_region_churn() {
        for shards in [2usize, 3, 7] {
            let mut sh = sharded(shards, 1, 100.0);
            let mut un = DdmEngine::builder().threads(2).build().session(1);
            let mut rng = Rng::new(0x54A2 + shards as u64);
            for _epoch in 0..8 {
                for _ in 0..50 {
                    let key = rng.below(30) as u32;
                    let lo = rng.uniform(0.0, 95.0);
                    let len = if rng.chance(0.3) {
                        rng.uniform(20.0, 70.0) // wider than a stripe
                    } else {
                        rng.uniform(0.1, 8.0)
                    };
                    let iv = Interval::new(lo, lo + len);
                    match rng.below(5) {
                        0 | 1 => {
                            sh.upsert_subscription(key, &[iv]);
                            un.upsert_subscription(key, &[iv]);
                        }
                        2 | 3 => {
                            sh.upsert_update(key, &[iv]);
                            un.upsert_update(key, &[iv]);
                        }
                        _ => {
                            sh.remove_update(key);
                            un.remove_update(key);
                        }
                    }
                }
                let (ds, du) = (sh.commit(), un.commit());
                assert_eq!(ds, du, "shards={shards}");
                assert_eq!(sh.pairs(), un.pairs(), "shards={shards}");
                assert_eq!(sh.n_pairs(), un.n_pairs());
            }
        }
    }

    #[test]
    fn flush_keeps_reads_live_and_epoch_open() {
        let mut sess = sharded(3, 1, 90.0);
        sess.upsert_subscription(1, &[Interval::new(10.0, 70.0)]);
        sess.upsert_update(2, &[Interval::new(55.0, 65.0)]);
        sess.flush();
        assert_eq!(sess.pending_ops(), 0);
        assert_eq!(sess.pairs(), vec![(1, 2)], "flushed state is readable");
        assert_eq!(sess.n_pairs(), 1, "n_pairs agrees with pairs() after flush");
        assert!(sess.contains_pair(1, 2));
        assert_eq!(sess.epoch(), 0, "flush does not close the epoch");
        let d = sess.commit();
        assert_eq!(d.added, vec![(1, 2)], "diff survives interleaved flush");
        assert_eq!(sess.n_pairs(), 1, "refcounts absorbed at commit");
    }

    /// Regression (wait-free reads): every read accessor answers from
    /// the cached merged snapshot — staged ops stay staged, no flush
    /// side effect is ever observable from a pure reader, and handed-
    /// out snapshots stay bit-identical across later commits.
    #[test]
    fn pure_reads_answer_from_the_merged_snapshot_without_routing() {
        let mut sess = sharded(3, 1, 90.0);
        sess.upsert_subscription(1, &[Interval::new(10.0, 70.0)]);
        sess.upsert_update(2, &[Interval::new(55.0, 65.0)]);
        sess.commit();
        let snap = sess.snapshot();
        assert_eq!(snap.epoch(), 1);
        assert_eq!(snap.pairs(), vec![(1, 2)]);
        assert_eq!(snap.n_subscriptions(), 1);
        assert_eq!(snap.n_updates(), 1);
        // Stage without applying: reads answer from the snapshot and
        // leave the staged ops untouched.
        sess.upsert_update(3, &[Interval::new(20.0, 30.0)]);
        let staged = sess.pending_ops();
        assert_eq!(sess.pairs(), vec![(1, 2)]);
        assert_eq!(sess.n_pairs(), 1);
        assert_eq!(sess.updates_of(1), vec![2]);
        assert_eq!(sess.subscriptions_of(2), vec![1]);
        assert!(sess.contains_pair(1, 2));
        assert!(!sess.contains_pair(1, 3));
        assert_eq!(sess.pending_ops(), staged, "a pure read routed staged ops");
        assert_eq!(sess.snapshot().epoch(), 1, "a pure read republished the snapshot");
        // The handed-out snapshot survives the next commit unchanged.
        sess.commit();
        assert_eq!(snap.pairs(), vec![(1, 2)], "published snapshot mutated");
        assert_eq!(sess.snapshot().epoch(), 2);
        assert_eq!(sess.updates_of(1), vec![2, 3]);
    }

    /// Queued ingest ops route through the sharded session exactly
    /// like directly staged ones.
    #[test]
    fn drain_ingest_routes_queued_ops_through_the_sharded_session() {
        let (tx, rx) = crate::session::ingest_queue(8);
        let mut sess = sharded(2, 1, 100.0);
        tx.try_upsert(Side::Subscription, 1, &[Interval::new(40.0, 60.0)]).unwrap();
        tx.try_upsert(Side::Update, 2, &[Interval::new(45.0, 55.0)]).unwrap();
        tx.try_remove(Side::Update, 7).unwrap();
        assert_eq!(sess.drain_ingest(&rx), 3);
        assert_eq!(rx.depth(), 0, "drained ops must release their slots");
        assert_eq!(sess.pending_ops(), 3);
        assert_eq!(sess.commit().added, vec![(1, 2)]);
        assert_eq!(sess.drain_ingest(&rx), 0);
    }

    #[test]
    fn balanced_strategy_samples_cuts_from_first_batch() {
        let engine = DdmEngine::builder().threads(1).build();
        let part = SpacePartitioner::uniform(4, 0, Interval::new(0.0, 1000.0));
        let mut sess = engine.sharded_session_with_strategy(1, part, ShardStrategy::Balanced);
        // 90% of regions inside [0, 100): balanced cuts must move into
        // the hotspot where uniform cuts (250/500/750) would not.
        let mut rng = Rng::new(0xBA1);
        for k in 0..200u32 {
            let lo = if k < 180 {
                rng.uniform(0.0, 95.0)
            } else {
                rng.uniform(100.0, 990.0)
            };
            sess.upsert_subscription(k, &[Interval::new(lo, lo + 5.0)]);
        }
        sess.commit();
        let cuts = sess.partitioner().cuts();
        assert_eq!(cuts.len(), 3);
        assert!(cuts[0] < 100.0 && cuts[1] < 100.0, "cuts {cuts:?}");
        // And the load is correspondingly spread out.
        assert!(sess.imbalance() < 2.0, "imbalance {}", sess.imbalance());
    }

    /// batch_threshold routes and applies eagerly on the sharded path
    /// too, without changing the committed diff.
    #[test]
    fn batch_threshold_auto_applies_staged_ops() {
        let part = SpacePartitioner::uniform(2, 0, Interval::new(0.0, 100.0));
        let mut sess = DdmEngine::builder()
            .threads(1)
            .batch_threshold(1)
            .build()
            .sharded_session_with(1, part);
        sess.upsert_subscription(1, &[Interval::new(40.0, 60.0)]);
        sess.upsert_update(2, &[Interval::new(45.0, 55.0)]); // pair appears, both shards
        assert_eq!(sess.pending_ops(), 0, "threshold applies eagerly");
        assert_eq!(sess.n_subscriptions(), 1, "routed state visible");
        sess.upsert_update(2, &[Interval::new(0.0, 10.0)]); // disappears, leaves shard 1
        sess.upsert_update(2, &[Interval::new(45.0, 55.0)]); // re-appears in both
        let d = sess.commit();
        assert_eq!(d.added, vec![(1, 2)], "intra-epoch churn cancels to one add");
        assert!(d.removed.is_empty());
    }

    /// Traced sharded commits put a ShardCommit span on every shard's
    /// lane, remap the inner sessions' phase spans onto the same lane,
    /// and close master-lane merge + commit envelopes; the measured
    /// per-shard durations feed the commit-time imbalance gauge (which
    /// works untraced too).
    #[test]
    fn traced_commit_emits_shard_lane_spans_and_timing() {
        use crate::obs::{trace::MASTER_WORKER, Phase};
        let part = SpacePartitioner::uniform(3, 0, Interval::new(0.0, 90.0));
        let mut sess = DdmEngine::builder()
            .threads(2)
            .parallel_cutoff(1)
            .trace(true)
            .build()
            .sharded_session_with(1, part);
        assert!(sess.trace_enabled());
        sess.upsert_subscription(1, &[Interval::new(0.0, 90.0)]); // all shards
        sess.upsert_update(2, &[Interval::new(40.0, 50.0)]);
        sess.commit();
        let spans = sess.drain_trace();
        assert_eq!(sess.trace_dropped(), 0);
        for shard in 0u16..3 {
            assert!(
                spans
                    .iter()
                    .any(|r| r.phase == Phase::ShardCommit.id() && r.worker == shard),
                "no ShardCommit span on lane {shard}: {spans:?}"
            );
            // Inner commit envelopes were remapped off the master lane.
            assert!(
                spans
                    .iter()
                    .any(|r| r.phase == Phase::Commit.id() && r.worker == shard),
                "no remapped inner Commit span on lane {shard}"
            );
        }
        let master = |p: Phase| {
            spans
                .iter()
                .any(|r| r.phase == p.id() && r.worker == MASTER_WORKER)
        };
        assert!(master(Phase::DiffMerge) && master(Phase::Commit));
        // Every ShardCommit span sits inside the master Commit envelope.
        let env = spans
            .iter()
            .find(|r| r.phase == Phase::Commit.id() && r.worker == MASTER_WORKER)
            .unwrap();
        for r in spans.iter().filter(|r| r.phase == Phase::ShardCommit.id()) {
            assert!(r.t0_ns >= env.t0_ns && r.t1_ns <= env.t1_ns, "{r:?} outside {env:?}");
        }
        // Second drain is empty; timing survives in the stats.
        assert!(sess.drain_trace().is_empty());
        let stats = sess.shard_stats();
        assert!(stats.iter().any(|s| s.last_commit_ns > 0));
        let im = ShardedSession::commit_time_imbalance_of(&stats).unwrap();
        assert!(im >= 1.0 && im <= stats.len() as f64, "{im}");

        // Untraced sessions still measure commit time, capture nothing.
        let mut off = sharded(2, 1, 100.0);
        assert!(!off.trace_enabled());
        off.upsert_subscription(1, &[Interval::new(10.0, 20.0)]);
        off.commit();
        assert!(off.drain_trace().is_empty());
        assert!(off.shard_stats().iter().any(|s| s.last_commit_ns > 0));
        assert!(ShardedSession::commit_time_imbalance_of(&off.shard_stats()).is_some());
    }

    #[test]
    fn commit_time_imbalance_is_none_before_any_commit() {
        let sess = sharded(4, 1, 100.0);
        assert!(ShardedSession::commit_time_imbalance_of(&sess.shard_stats()).is_none());
    }

    #[test]
    fn imbalance_gauge_tracks_skew() {
        let mut sess = sharded(4, 1, 100.0);
        assert_eq!(sess.imbalance(), 1.0, "empty session is balanced");
        for k in 0..40u32 {
            sess.upsert_subscription(k, &[Interval::new(1.0, 2.0)]); // all in stripe 0
        }
        sess.commit();
        assert!((sess.imbalance() - 4.0).abs() < 1e-9, "{}", sess.imbalance());
        let stats = sess.shard_stats();
        assert_eq!(stats[0].subscriptions, 40);
        assert_eq!(stats[0].last_ops, 40);
        assert_eq!(stats[1].subscriptions, 0);
    }

    /// Parallel fan-out (threads > 1, cutoff 0) and the serial path
    /// produce identical merged diffs.
    #[test]
    fn parallel_and_serial_fanout_agree() {
        let engine_par = DdmEngine::builder().threads(4).parallel_cutoff(1).build();
        let engine_ser = DdmEngine::builder().threads(1).build();
        let part = || SpacePartitioner::uniform(5, 0, Interval::new(0.0, 100.0));
        let mut a = engine_par.sharded_session_with(1, part());
        let mut b = engine_ser.sharded_session_with(1, part());
        let mut rng = Rng::new(0x54A3);
        for _ in 0..6 {
            for _ in 0..80 {
                let key = rng.below(40) as u32;
                let lo = rng.uniform(0.0, 90.0);
                let iv = Interval::new(lo, lo + rng.uniform(1.0, 40.0));
                if rng.chance(0.5) {
                    a.upsert_subscription(key, &[iv]);
                    b.upsert_subscription(key, &[iv]);
                } else {
                    a.upsert_update(key, &[iv]);
                    b.upsert_update(key, &[iv]);
                }
            }
            assert_eq!(a.commit(), b.commit());
        }
        assert_eq!(a.pairs(), b.pairs());
    }
}

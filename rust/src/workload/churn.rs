//! Deterministic churn scripts: the shared move generator behind
//! `ddm replay` and `benches/abl_session.rs`.
//!
//! Comparing the session diff path against the rebuild baseline is
//! only honest when both replay the *identical* move sequence. A
//! [`MoveScript`] owns the RNG and hands out side/index/position
//! decisions; two consumers seeded identically stay in lockstep no
//! matter which matching path they drive. [`relocate`] applies one
//! move to a dense region array (keeping the region's length, which is
//! what the α-model and the Köln trace both assume), and
//! [`diff_pair_counts`] derives the `(added, removed)` sizes the
//! rebuild path must pay to compute explicitly.

use crate::core::interval::Interval;
use crate::core::Regions1D;
use crate::prng::Rng;

/// Fraction of the space the hotspot corner occupies (low end).
const HOTSPOT_CORNER: f64 = 0.1;

/// A reproducible stream of region moves, optionally skewed: a
/// `hotspot` fraction of moves relocates into the low-corner tenth of
/// the space instead of uniformly, concentrating load the way a
/// congested intersection (or one hot spatial shard) would. With
/// `hotspot == 0.0` the stream is bit-identical to the historical
/// [`MoveScript::new`] behavior.
pub struct MoveScript {
    rng: Rng,
    hotspot: f64,
}

impl MoveScript {
    /// Uniform moves (no skew).
    pub fn new(seed: u64) -> Self {
        Self::with_hotspot(seed, 0.0)
    }

    /// `hotspot ∈ [0, 1]`: probability that a move targets the
    /// low-corner tenth of the space. This is what makes shard
    /// imbalance exercisable — `benches/abl_shard.rs` drives it.
    pub fn with_hotspot(seed: u64, hotspot: f64) -> Self {
        Self {
            rng: Rng::new(seed),
            hotspot: hotspot.clamp(0.0, 1.0),
        }
    }

    /// The next move: `(subscription side?, dense index, position
    /// fraction in [0, 1))`. Consumes the RNG identically regardless
    /// of how the caller applies the move.
    pub fn next(&mut self, n_subs: usize, n_upds: usize) -> (bool, usize, f64) {
        let sub_side = self.rng.chance(0.5);
        let idx = if sub_side {
            self.rng.below(n_subs as u64)
        } else {
            self.rng.below(n_upds as u64)
        } as usize;
        let mut frac = self.rng.uniform(0.0, 1.0);
        if self.hotspot > 0.0 && self.rng.chance(self.hotspot) {
            frac *= HOTSPOT_CORNER; // drift toward the low corner
        }
        (sub_side, idx, frac)
    }
}

/// Relocate region `idx` to position fraction `frac` of `[0, space_hi)`,
/// keeping its length; returns the new interval.
pub fn relocate(regions: &mut Regions1D, idx: usize, frac: f64, space_hi: f64) -> Interval {
    let l = regions.get(idx).len();
    let lo = frac * (space_hi - l).max(0.0);
    let iv = Interval::new(lo, lo + l);
    regions.set(idx, iv);
    iv
}

/// `(added, removed)` = `(|new \ old|, |old \ new|)` over two sorted
/// pair lists — the delta the rebuild baseline derives by re-diffing
/// full match results (a session reads it off its `MatchDiff`).
pub fn diff_pair_counts(old: &[(u32, u32)], new: &[(u32, u32)]) -> (usize, usize) {
    let (mut i, mut j) = (0usize, 0usize);
    let (mut removed, mut added) = (0usize, 0usize);
    while i < old.len() && j < new.len() {
        match old[i].cmp(&new[j]) {
            std::cmp::Ordering::Less => {
                removed += 1;
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                added += 1;
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
        }
    }
    removed += old.len() - i;
    added += new.len() - j;
    (added, removed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripts_with_equal_seeds_are_lockstep() {
        let mut a = MoveScript::new(9);
        let mut b = MoveScript::new(9);
        for _ in 0..50 {
            assert_eq!(a.next(100, 80), b.next(100, 80));
        }
    }

    #[test]
    fn hotspot_skews_positions_toward_the_corner() {
        let mut hot = MoveScript::with_hotspot(11, 0.8);
        let mut cold = MoveScript::with_hotspot(12, 0.0);
        let corner = |s: &mut MoveScript| {
            (0..2000)
                .filter(|_| s.next(100, 100).2 < HOTSPOT_CORNER)
                .count()
        };
        let (n_hot, n_cold) = (corner(&mut hot), corner(&mut cold));
        // ~84% of hot moves land in the corner vs ~10% of cold ones.
        assert!(n_hot > 1400, "hot corner hits: {n_hot}");
        assert!(n_cold < 400, "cold corner hits: {n_cold}");
        // Equal seeds with equal hotspot remain lockstep.
        let mut a = MoveScript::with_hotspot(9, 0.5);
        let mut b = MoveScript::with_hotspot(9, 0.5);
        for _ in 0..50 {
            assert_eq!(a.next(10, 10), b.next(10, 10));
        }
    }

    #[test]
    fn relocate_keeps_length_and_bounds() {
        let mut r = Regions1D::from_intervals(&[Interval::new(10.0, 25.0)]);
        let iv = relocate(&mut r, 0, 0.5, 100.0);
        assert!((iv.len() - 15.0).abs() < 1e-9);
        assert!(iv.lo >= 0.0 && iv.hi <= 100.0);
        assert_eq!(r.get(0), iv);
    }

    #[test]
    fn diff_pair_counts_two_pointer() {
        let old = vec![(0, 0), (1, 1), (2, 2)];
        let new = vec![(1, 1), (2, 3), (5, 5)];
        assert_eq!(diff_pair_counts(&old, &new), (2, 2));
        assert_eq!(diff_pair_counts(&[], &old), (3, 0));
        assert_eq!(diff_pair_counts(&old, &[]), (0, 3));
        assert_eq!(diff_pair_counts(&old, &old), (0, 0));
    }
}

//! Köln-trace-like vehicular workload (paper Fig. 14 substitution).
//!
//! The paper uses the TAPASCologne trace [62]: 541,222 vehicle
//! positions from the greater Cologne area (400 km²); the x coordinate
//! of each position centers one subscription and one update region of
//! width 100 m, giving N ≈ 10⁶ regions and ≈ 3.9×10⁹ intersections.
//!
//! The trace is not downloadable in this offline environment, so this
//! generator synthesizes a trace with the documented statistics
//! (DESIGN.md §3, substitution 2): vehicle x-positions are drawn from a
//! mixture of Gaussian "arterial road" clusters over a ~15 km urban
//! extent plus a uniform background — 15 km is the extent at which
//! uniform placement of 541,222 double regions of 100 m width yields
//! the paper's ≈3.9×10⁹ intersections (E[K] = n·m·2w/L). The achieved
//! count is printed by `benches/fig14_koln.rs` and recorded in
//! EXPERIMENTS.md.

use crate::core::{Interval, Regions1D};
use crate::prng::Rng;

/// Trace parameters (defaults mirror the paper's setup).
#[derive(Debug, Clone, Copy)]
pub struct KolnParams {
    /// Number of vehicle positions (each yields 1 sub + 1 upd region).
    pub positions: usize,
    /// Region width in meters (paper: 100 m).
    pub width: f64,
    /// Urban extent in meters.
    pub extent: f64,
    /// Number of arterial-road clusters.
    pub clusters: usize,
    /// Fraction of vehicles on arterials (vs uniform background).
    pub arterial_fraction: f64,
}

impl Default for KolnParams {
    fn default() -> Self {
        Self {
            positions: 541_222,
            width: 100.0,
            extent: 15_000.0,
            clusters: 12,
            arterial_fraction: 0.7,
        }
    }
}

impl KolnParams {
    /// Scale the position count (benches use fractions of the full trace).
    pub fn scaled(mut self, factor: f64) -> Self {
        self.positions = ((self.positions as f64 * factor) as usize).max(1);
        self
    }
}

/// Generate the trace: `(subscriptions, updates)`, one of each per
/// vehicle position, both centered on the vehicle's x coordinate.
pub fn koln_workload(seed: u64, p: &KolnParams) -> (Regions1D, Regions1D) {
    let mut rng = Rng::new(seed);
    // Arterial clusters: position + spread (big roads are long).
    let roads: Vec<(f64, f64)> = (0..p.clusters.max(1))
        .map(|_| {
            let center = rng.uniform(0.05 * p.extent, 0.95 * p.extent);
            let sigma = rng.uniform(0.005 * p.extent, 0.03 * p.extent);
            (center, sigma)
        })
        .collect();
    let half = p.width / 2.0;
    let mut subs = Regions1D::with_capacity(p.positions);
    let mut upds = Regions1D::with_capacity(p.positions);
    for _ in 0..p.positions {
        let x = if rng.chance(p.arterial_fraction) {
            let (c, s) = roads[rng.below(roads.len() as u64) as usize];
            (c + rng.gaussian() * s).clamp(0.0, p.extent)
        } else {
            rng.uniform(0.0, p.extent)
        };
        let lo = (x - half).max(0.0);
        let hi = (x + half).min(p.extent);
        subs.push(Interval::new(lo, hi));
        upds.push(Interval::new(lo, hi));
    }
    (subs, upds)
}

/// Write positions to a simple CSV (`x` per line) for trace replay.
pub fn save_positions_csv(path: &std::path::Path, subs: &Regions1D) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "x")?;
    for iv in subs.iter() {
        writeln!(f, "{}", (iv.lo + iv.hi) / 2.0)?;
    }
    Ok(())
}

/// Load positions from CSV and rebuild the workload.
pub fn load_positions_csv(
    path: &std::path::Path,
    width: f64,
) -> std::io::Result<(Regions1D, Regions1D)> {
    let text = std::fs::read_to_string(path)?;
    let half = width / 2.0;
    let mut subs = Regions1D::default();
    let mut upds = Regions1D::default();
    for line in text.lines().skip(1) {
        if let Ok(x) = line.trim().parse::<f64>() {
            let iv = Interval::new(x - half, x + half);
            subs.push(iv);
            upds.push(iv);
        }
    }
    Ok((subs, upds))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_bounds() {
        let p = KolnParams::default().scaled(0.01);
        let (s, u) = koln_workload(1, &p);
        assert_eq!(s.len(), 5412);
        assert_eq!(u.len(), 5412);
        for iv in s.iter() {
            assert!(iv.lo >= 0.0 && iv.hi <= p.extent);
            assert!(iv.len() <= p.width + 1e-9);
        }
    }

    #[test]
    fn intersection_density_matches_paper_scale() {
        // At 1% scale, K should scale as (0.01)² of ≈3.9e9 → ≈3.9e5,
        // within a factor of ~4 (clustering adds variance).
        let p = KolnParams::default().scaled(0.01);
        let (s, u) = koln_workload(2, &p);
        let mut sink = crate::core::sink::CountSink::default();
        crate::algos::bfm::match_seq(&s, &u, &mut sink);
        let k = sink.count as f64;
        let target = 3.9e9 * 0.01 * 0.01;
        let ratio = k / target;
        assert!(
            (0.25..4.0).contains(&ratio),
            "K={k} vs scaled paper target {target}"
        );
    }

    #[test]
    fn csv_roundtrip() {
        let p = KolnParams::default().scaled(0.001);
        let (s, _) = koln_workload(3, &p);
        let path = std::env::temp_dir().join("ddm_koln_test.csv");
        save_positions_csv(&path, &s).unwrap();
        let (s2, u2) = load_positions_csv(&path, p.width).unwrap();
        assert_eq!(s2.len(), s.len());
        assert_eq!(u2.len(), s.len());
        std::fs::remove_file(&path).ok();
    }
}

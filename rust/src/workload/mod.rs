//! Workload generators for the evaluation (paper §5).
//!
//! * [`synthetic`] — the paper's α-model: N fixed-length regions placed
//!   uniformly on a segment, `α = N·l/L` (plus a clustered variant for
//!   the GBM discussion of skewed cells).
//! * [`koln`] — a Köln-trace-like vehicular workload (Fig. 14
//!   substitution; the real trace is not downloadable offline —
//!   DESIGN.md §3 documents the substitution).
//! * [`nd`] — d-dimensional workloads: the anisotropic per-dimension
//!   α-model (per-dimension selectivity skews) and a correlated
//!   variant (centers tracking dimension 0) for exercising the native
//!   N-D pipeline.
//! * [`churn`] — deterministic region-move scripts for replaying the
//!   same churn through the session and rebuild paths.

pub mod churn;
pub mod koln;
pub mod nd;
pub mod synthetic;

pub use nd::{nd_alpha_workload, nd_correlated_workload, NdAlphaParams};
pub use synthetic::{alpha_workload, clustered_workload, AlphaParams};

//! Workload generators for the evaluation (paper §5).
//!
//! * [`synthetic`] — the paper's α-model: N fixed-length regions placed
//!   uniformly on a segment, `α = N·l/L` (plus a clustered variant for
//!   the GBM discussion of skewed cells).
//! * [`koln`] — a Köln-trace-like vehicular workload (Fig. 14
//!   substitution; the real trace is not downloadable offline —
//!   DESIGN.md §3 documents the substitution).
//! * [`churn`] — deterministic region-move scripts for replaying the
//!   same churn through the session and rebuild paths.

pub mod churn;
pub mod koln;
pub mod synthetic;

pub use synthetic::{alpha_workload, clustered_workload, AlphaParams};

//! d-dimensional synthetic workloads: the per-dimension (anisotropic)
//! α-model and a correlated variant.
//!
//! The 1-D α-model ([`super::synthetic`]) fixes one overlapping degree
//! α = N·l/L. Real N-D scenarios are rarely isotropic: a Köln-style
//! traffic workload has sharp spatial extents but a time (or road-id)
//! dimension that barely discriminates. [`NdAlphaParams`] gives every
//! dimension its own α_k, so per-dimension selectivity skews are a
//! first-class knob — exactly the regime where the native
//! sweep-and-verify pipeline ([`crate::core::ddim`]) beats the
//! per-dimension reduction (`benches/abl_nd.rs` measures it).
//!
//! [`nd_correlated_workload`] additionally correlates every
//! dimension's placement with dimension 0 (centers drawn along the
//! diagonal plus Gaussian noise) — each 1-D projection stays dense
//! while the joint result concentrates, the worst case for any
//! per-dimension combine.

use crate::core::interval::Interval;
use crate::core::RegionsNd;
use crate::prng::Rng;

/// Parameters of the anisotropic d-dimensional α-model.
#[derive(Debug, Clone)]
pub struct NdAlphaParams {
    /// Total number of regions N (split evenly into S and U).
    pub n_total: usize,
    /// Per-dimension overlapping degrees; `d = alphas.len()`.
    /// `α_k = N·l_k/L` fixes each dimension's region extent
    /// `l_k = α_k·L/N` (clamped to the space).
    pub alphas: Vec<f64>,
    /// Routing-space length L per dimension (paper: 10⁶).
    pub space: f64,
}

impl NdAlphaParams {
    /// Isotropic d-dimensional model: the same α on every dimension.
    pub fn iso(d: usize, n_total: usize, alpha: f64, space: f64) -> Self {
        assert!(d >= 1);
        Self {
            n_total,
            alphas: vec![alpha; d],
            space,
        }
    }

    /// Anisotropic model from explicit per-dimension α's.
    pub fn skewed(n_total: usize, alphas: &[f64], space: f64) -> Self {
        assert!(!alphas.is_empty());
        Self {
            n_total,
            alphas: alphas.to_vec(),
            space,
        }
    }

    pub fn d(&self) -> usize {
        self.alphas.len()
    }

    /// Region extent on dimension `k`: `l_k = α_k·L/N`, clamped to L.
    pub fn region_len(&self, k: usize) -> f64 {
        (self.alphas[k] * self.space / self.n_total as f64).min(self.space)
    }
}

/// Generate `count` rectangles. Dimension 0's center is the anchor
/// `c0 ~ U[0, L)`; every other dimension's center comes from
/// `center(rng, k, c0)` (clamped into the space).
fn gen_rects<F>(rng: &mut Rng, p: &NdAlphaParams, count: usize, mut center: F) -> RegionsNd
where
    F: FnMut(&mut Rng, usize, f64) -> f64,
{
    let d = p.d();
    let lens: Vec<f64> = (0..d).map(|k| p.region_len(k)).collect();
    let mut out = RegionsNd::new(d);
    let mut rect = vec![Interval::new(0.0, 0.0); d];
    for _ in 0..count {
        let c0 = rng.uniform(0.0, p.space);
        for k in 0..d {
            let c = if k == 0 { c0 } else { center(rng, k, c0) };
            let lo = (c - lens[k] * 0.5).clamp(0.0, p.space - lens[k]);
            rect[k] = Interval::new(lo, lo + lens[k]);
        }
        out.push(&rect);
    }
    out
}

/// Anisotropic uniform placement: every dimension's center drawn
/// independently, extents fixed per dimension by `alphas`. Returns
/// `(subscriptions, updates)`.
pub fn nd_alpha_workload(seed: u64, p: &NdAlphaParams) -> (RegionsNd, RegionsNd) {
    let mut rng = Rng::new(seed);
    let n = p.n_total / 2;
    let m = p.n_total - n;
    let space = p.space;
    let subs = gen_rects(&mut rng, p, n, |rng, _k, _c0| rng.uniform(0.0, space));
    let upds = gen_rects(&mut rng, p, m, |rng, _k, _c0| rng.uniform(0.0, space));
    (subs, upds)
}

/// Correlated placement: dimension k's center tracks dimension 0's
/// (`c_k = c_0 + N(0, σ)` with `σ = (1 - rho) · L`), so `rho = 1`
/// puts every rectangle on the diagonal and `rho = 0` degenerates to
/// (nearly) independent placement. Models Köln-style trajectories
/// where position and time advance together.
pub fn nd_correlated_workload(seed: u64, p: &NdAlphaParams, rho: f64) -> (RegionsNd, RegionsNd) {
    assert!((0.0..=1.0).contains(&rho), "rho must be in [0, 1]");
    let mut rng = Rng::new(seed);
    let sigma = (1.0 - rho) * p.space;
    let n = p.n_total / 2;
    let m = p.n_total - n;
    let subs = gen_rects(&mut rng, p, n, |rng, _k, c0| c0 + rng.gaussian() * sigma);
    let upds = gen_rects(&mut rng, p, m, |rng, _k, c0| c0 + rng.gaussian() * sigma);
    (subs, upds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_bounds() {
        let p = NdAlphaParams::skewed(1001, &[100.0, 1.0, 0.01], 1e5);
        assert_eq!(p.d(), 3);
        let (s, u) = nd_alpha_workload(7, &p);
        assert_eq!(s.d(), 3);
        assert_eq!(s.len(), 500);
        assert_eq!(u.len(), 501);
        for regions in [&s, &u] {
            for k in 0..3 {
                let l = p.region_len(k);
                for iv in regions.project(k).iter() {
                    assert!(iv.lo >= 0.0 && iv.hi <= p.space);
                    assert!((iv.len() - l).abs() < 1e-9, "dim {k}");
                }
            }
        }
        // Per-dimension extents follow the per-dimension α's.
        assert!(p.region_len(0) > p.region_len(1));
        assert!(p.region_len(1) > p.region_len(2));
    }

    #[test]
    fn deterministic_per_seed() {
        let p = NdAlphaParams::iso(2, 200, 5.0, 1e4);
        let (a, _) = nd_alpha_workload(9, &p);
        let (b, _) = nd_alpha_workload(9, &p);
        assert_eq!(a.project(1).lo, b.project(1).lo);
        let (c, _) = nd_alpha_workload(10, &p);
        assert_ne!(a.project(0).lo, c.project(0).lo);
    }

    #[test]
    fn anisotropy_skews_per_dimension_pair_counts() {
        // α₀ ≫ α₁: dimension 0's projections must produce far more 1-D
        // pairs than dimension 1's.
        let p = NdAlphaParams::skewed(2000, &[200.0, 1.0], 1e5);
        let (s, u) = nd_alpha_workload(3, &p);
        let count_1d = |k: usize| {
            let mut sink = crate::core::sink::CountSink::default();
            crate::algos::bfm::match_seq(s.project(k), u.project(k), &mut sink);
            sink.count
        };
        assert!(
            count_1d(0) > 20 * count_1d(1),
            "K0={} K1={}",
            count_1d(0),
            count_1d(1)
        );
    }

    #[test]
    fn correlation_concentrates_joint_matches() {
        // Same per-dimension α's: the correlated workload has (much)
        // more joint N-D intersection than the independent one, while
        // each projection's density is comparable.
        let p = NdAlphaParams::iso(2, 1000, 20.0, 1e5);
        let joint = |w: &(RegionsNd, RegionsNd)| {
            let (s, u) = w;
            let mut k = 0u64;
            for i in 0..s.len() {
                for j in 0..u.len() {
                    if s.rects_intersect(i, u, j) {
                        k += 1;
                    }
                }
            }
            k
        };
        let indep = nd_alpha_workload(5, &p);
        let corr = nd_correlated_workload(5, &p, 0.999);
        assert!(
            joint(&corr) > 4 * joint(&indep).max(1),
            "corr={} indep={}",
            joint(&corr),
            joint(&indep)
        );
    }

    #[test]
    #[should_panic(expected = "rho must be in")]
    fn correlation_rho_is_validated() {
        let p = NdAlphaParams::iso(2, 10, 1.0, 1e3);
        let _ = nd_correlated_workload(1, &p, 1.5);
    }
}

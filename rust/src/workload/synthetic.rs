//! The paper's synthetic workload (§5, methodology of Raczy et al. [52]).
//!
//! N total regions (n = N/2 subscriptions, m = N/2 updates), all of the
//! same length `l`, placed uniformly at random on a segment of length
//! `L = 10⁶`. The *overlapping degree* `α = N·l/L` fixes `l = αL/N`;
//! the paper uses α ∈ {0.01, 1, 100}.

use crate::core::region::random_regions_1d;
use crate::core::Regions1D;
use crate::prng::Rng;

/// Parameters of the α-model.
#[derive(Debug, Clone, Copy)]
pub struct AlphaParams {
    /// Total number of regions N (split evenly into S and U).
    pub n_total: usize,
    /// Overlapping degree α.
    pub alpha: f64,
    /// Routing-space length L (paper: 10⁶).
    pub space: f64,
}

impl Default for AlphaParams {
    fn default() -> Self {
        Self {
            n_total: 1_000_000,
            alpha: 100.0,
            space: 1e6,
        }
    }
}

impl AlphaParams {
    /// Region length l = αL/N.
    pub fn region_len(&self) -> f64 {
        (self.alpha * self.space / self.n_total as f64).min(self.space)
    }
}

/// Generate the paper's uniform workload: `(subscriptions, updates)`.
pub fn alpha_workload(seed: u64, p: &AlphaParams) -> (Regions1D, Regions1D) {
    let mut rng = Rng::new(seed);
    let l = p.region_len();
    let n = p.n_total / 2;
    let m = p.n_total - n;
    let subs = random_regions_1d(&mut rng, n, p.space, l);
    let upds = random_regions_1d(&mut rng, m, p.space, l);
    (subs, upds)
}

/// Clustered variant: region centers drawn from `k` Gaussian clusters
/// (models the "localized cluster of interacting agents" that breaks
/// GBM's uniform-cell assumption, paper §2).
pub fn clustered_workload(
    seed: u64,
    p: &AlphaParams,
    k_clusters: usize,
    sigma: f64,
) -> (Regions1D, Regions1D) {
    let mut rng = Rng::new(seed);
    let l = p.region_len();
    let centers: Vec<f64> = (0..k_clusters.max(1))
        .map(|_| rng.uniform(0.1 * p.space, 0.9 * p.space))
        .collect();
    let mut gen = |count: usize| {
        let mut out = Regions1D::with_capacity(count);
        for _ in 0..count {
            let c = centers[rng.below(centers.len() as u64) as usize];
            let x = (c + rng.gaussian() * sigma).clamp(0.0, p.space - l);
            out.push(crate::core::Interval::new(x, x + l));
        }
        out
    };
    let n = p.n_total / 2;
    let subs = gen(n);
    let upds = gen(p.n_total - n);
    (subs, upds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_len_from_alpha() {
        let p = AlphaParams {
            n_total: 1_000_000,
            alpha: 100.0,
            space: 1e6,
        };
        assert!((p.region_len() - 100.0).abs() < 1e-9);
        let tiny = AlphaParams {
            n_total: 100,
            alpha: 0.01,
            space: 1e6,
        };
        assert!((tiny.region_len() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn workload_shapes_and_bounds() {
        let p = AlphaParams {
            n_total: 10_001,
            alpha: 1.0,
            space: 1e6,
        };
        let (s, u) = alpha_workload(7, &p);
        assert_eq!(s.len(), 5000);
        assert_eq!(u.len(), 5001);
        let l = p.region_len();
        for iv in s.iter().chain(u.iter()) {
            assert!(iv.lo >= 0.0 && iv.hi <= p.space);
            assert!((iv.len() - l).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let p = AlphaParams {
            n_total: 100,
            alpha: 1.0,
            space: 1e3,
        };
        let (a, _) = alpha_workload(9, &p);
        let (b, _) = alpha_workload(9, &p);
        assert_eq!(a.lo, b.lo);
        let (c, _) = alpha_workload(10, &p);
        assert_ne!(a.lo, c.lo);
    }

    #[test]
    fn alpha_predicts_intersections() {
        // E[K] ≈ n·m·2l/L for uniform placement; α=N·l/L ties them.
        // Verify the empirical count is within 3x of the estimate.
        let p = AlphaParams {
            n_total: 2000,
            alpha: 10.0,
            space: 1e5,
        };
        let (s, u) = alpha_workload(3, &p);
        let mut sink = crate::core::sink::CountSink::default();
        crate::algos::bfm::match_seq(&s, &u, &mut sink);
        let l = p.region_len();
        let expect = (s.len() * u.len()) as f64 * 2.0 * l / p.space;
        let ratio = sink.count as f64 / expect;
        assert!((0.3..3.0).contains(&ratio), "K={} expect~{expect}", sink.count);
    }

    #[test]
    fn clustered_is_denser_than_uniform() {
        let p = AlphaParams {
            n_total: 2000,
            alpha: 1.0,
            space: 1e5,
        };
        let (su, uu) = alpha_workload(5, &p);
        let (sc, uc) = clustered_workload(5, &p, 3, 500.0);
        let count = |s: &Regions1D, u: &Regions1D| {
            let mut sink = crate::core::sink::CountSink::default();
            crate::algos::bfm::match_seq(s, u, &mut sink);
            sink.count
        };
        assert!(count(&sc, &uc) > 2 * count(&su, &uu));
    }
}

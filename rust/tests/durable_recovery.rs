//! Crash-recovery property suite for the durability layer.
//!
//! Strategy: drive a deterministic random churn script through a
//! WAL-attached session, recording the log length after every commit
//! (the marker boundaries), then simulate crashes by truncating a copy
//! of the log at every structurally valid record end, mid-record (torn
//! writes), and under single bit-flips. Each crash image is recovered
//! into a fresh engine and compared against an *oracle*: a WAL-less
//! session that replayed exactly the script prefix the surviving
//! markers cover. The invariants, from the durability contract
//! (`rust/src/durable/mod.rs`):
//!
//! * recovery never panics and never surfaces a partial epoch — the
//!   recovered epoch is exactly the number of commit markers intact in
//!   the crash image;
//! * the recovered state (epoch, pair set, per-key queries) is
//!   bit-equal to the prefix-replay oracle at that epoch;
//! * this holds unsharded and sharded, for d ∈ {1, 3}, with and
//!   without checkpoint files, and a history recorded in one session
//!   shape recovers in the other;
//! * a recovered session resumes logging, so a second crash after the
//!   resume recovers the continuation too.

use std::path::{Path, PathBuf};

use ddm::core::Interval;
use ddm::durable::{snapfile, wal, RecoverReport};
use ddm::engine::DdmEngine;
use ddm::prng::Rng;
use ddm::shard::AnySession;

const SPACE: f64 = 1_000.0;
const KEYS: u32 = 16;

/// One scripted staging op — the suite's own type so the oracle and
/// the durable run share a replayable description of the workload.
#[derive(Clone)]
enum Op {
    UpsertSub { key: u32, rect: Vec<Interval> },
    UpsertUpd { key: u32, rect: Vec<Interval> },
    RemoveSub { key: u32 },
    RemoveUpd { key: u32 },
}

fn random_rect(rng: &mut Rng, d: usize) -> Vec<Interval> {
    (0..d)
        .map(|_| {
            let lo = rng.uniform(0.0, SPACE * 0.9);
            let hi = (lo + rng.uniform(0.01, 0.25) * SPACE).min(SPACE);
            Interval::new(lo, hi)
        })
        .collect()
}

/// Deterministic churn script: epoch 1 seeds every key on both sides,
/// later epochs upsert (80%) or remove (20%) random keys.
fn churn_script(seed: u64, d: usize, epochs: usize, ops_per_epoch: usize) -> Vec<Vec<Op>> {
    let mut rng = Rng::new(seed);
    let mut script = Vec::with_capacity(epochs);
    let mut first = Vec::with_capacity(2 * KEYS as usize);
    for key in 0..KEYS {
        first.push(Op::UpsertSub { key, rect: random_rect(&mut rng, d) });
        first.push(Op::UpsertUpd { key, rect: random_rect(&mut rng, d) });
    }
    script.push(first);
    for _ in 1..epochs {
        let mut ops = Vec::with_capacity(ops_per_epoch);
        for _ in 0..ops_per_epoch {
            let key = rng.below(u64::from(KEYS)) as u32;
            let sub_side = rng.chance(0.5);
            ops.push(match (rng.chance(0.8), sub_side) {
                (true, true) => Op::UpsertSub { key, rect: random_rect(&mut rng, d) },
                (true, false) => Op::UpsertUpd { key, rect: random_rect(&mut rng, d) },
                (false, true) => Op::RemoveSub { key },
                (false, false) => Op::RemoveUpd { key },
            });
        }
        script.push(ops);
    }
    script
}

fn apply(sess: &mut AnySession, ops: &[Op]) {
    for op in ops {
        match op {
            Op::UpsertSub { key, rect } => sess.upsert_subscription(*key, rect),
            Op::UpsertUpd { key, rect } => sess.upsert_update(*key, rect),
            Op::RemoveSub { key } => sess.remove_subscription(*key),
            Op::RemoveUpd { key } => sess.remove_update(*key),
        }
    }
}

/// Everything the suite compares between a recovered session and the
/// oracle: epoch, the full pair set, and both per-key query directions
/// for every key (sorted, so single and sharded sessions digest equal).
#[derive(Debug, Clone, PartialEq)]
struct Digest {
    epoch: u64,
    n_pairs: usize,
    pairs: Vec<(u32, u32)>,
    updates_of: Vec<Vec<u32>>,
    subscriptions_of: Vec<Vec<u32>>,
}

fn digest(sess: &AnySession) -> Digest {
    let mut pairs = sess.pairs();
    pairs.sort_unstable();
    let sorted = |mut v: Vec<u32>| {
        v.sort_unstable();
        v
    };
    Digest {
        epoch: sess.epoch(),
        n_pairs: sess.n_pairs(),
        pairs,
        updates_of: (0..KEYS).map(|k| sorted(sess.updates_of(k))).collect(),
        subscriptions_of: (0..KEYS).map(|k| sorted(sess.subscriptions_of(k))).collect(),
    }
}

/// Prefix-replay oracle: digests[e] is the state a WAL-less session
/// holds after committing the first `e` epochs of the script.
fn oracle_digests(d: usize, script: &[Vec<Op>]) -> Vec<Digest> {
    let engine = DdmEngine::builder().threads(1).build();
    let mut sess = engine.any_session(d, Interval::new(0.0, SPACE));
    let mut digests = Vec::with_capacity(script.len() + 1);
    digests.push(digest(&sess));
    for ops in script {
        apply(&mut sess, ops);
        sess.commit();
        digests.push(digest(&sess));
    }
    digests
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ddm-durprop-{tag}-{}", std::process::id()))
}

/// What a durable run left behind: the raw log image, the log length
/// right after each commit (byte position of each marker's end — the
/// independent crash-point ↦ epoch map), the snapshot file if the
/// checkpoint cadence installed one, and the live session's digest.
struct History {
    log: Vec<u8>,
    commit_lens: Vec<u64>,
    snap: Option<Vec<u8>>,
    live: Digest,
}

fn record_history(
    dir: &Path,
    d: usize,
    shards: usize,
    snapshot_every: u64,
    script: &[Vec<Op>],
) -> History {
    let _ = std::fs::remove_dir_all(dir);
    let mut builder = DdmEngine::builder()
        .threads(1)
        .durability(dir)
        .durability_snapshot_every(snapshot_every);
    if shards > 1 {
        builder = builder.shards(shards);
    }
    let engine = builder.build();
    let mut sess = engine.any_session(d, Interval::new(0.0, SPACE));
    let mut commit_lens = Vec::with_capacity(script.len());
    for ops in script {
        apply(&mut sess, ops);
        sess.commit();
        let len = std::fs::metadata(dir.join(wal::LOG_FILE)).expect("log metadata").len();
        commit_lens.push(len);
    }
    assert_eq!(sess.wal_error(), None, "durable run degraded its WAL");
    History {
        log: std::fs::read(dir.join(wal::LOG_FILE)).expect("read log"),
        commit_lens,
        snap: std::fs::read(dir.join(snapfile::SNAP_FILE)).ok(),
        live: digest(&sess),
    }
}

/// Install a crash image: a fresh directory holding `log` (and
/// optionally a snapshot file) as a kill -9 would have left them.
fn install_crash_image(dir: &Path, log: &[u8], snap: Option<&[u8]>) {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).expect("create crash dir");
    std::fs::write(dir.join(wal::LOG_FILE), log).expect("write crash log");
    if let Some(bytes) = snap {
        std::fs::write(dir.join(snapfile::SNAP_FILE), bytes).expect("write crash snapshot");
    }
}

fn recover(dir: &Path, d: usize, shards: usize) -> ddm::Result<(AnySession, RecoverReport)> {
    let mut builder = DdmEngine::builder().threads(1).durability(dir);
    if shards > 1 {
        builder = builder.shards(shards);
    }
    builder.build().recover_any_session(d, Interval::new(0.0, SPACE))
}

/// Number of commit markers fully contained in the first `cut` bytes —
/// the epoch a crash at that byte must recover to. Computed from the
/// recorded post-commit lengths, independently of the scanner.
fn expected_epoch(cut: u64, commit_lens: &[u64]) -> u64 {
    commit_lens.iter().filter(|&&len| len <= cut).count() as u64
}

#[test]
fn cuts_at_every_record_boundary_recover_the_exact_marker_prefix() {
    let d = 1;
    let script = churn_script(0xD1CE, d, 6, 10);
    let oracle = oracle_digests(d, &script);
    let dir = tmp("bound");
    let hist = record_history(&dir, d, 1, u64::MAX, &script);
    assert_eq!(hist.live, oracle[script.len()], "durable run diverged from the oracle");
    assert!(hist.snap.is_none(), "checkpoints were disabled");

    let scan = wal::scan_log(&hist.log);
    assert_eq!(scan.batches.len(), script.len());
    assert_eq!(scan.tail_bytes, 0, "a clean shutdown leaves no tail");
    for &len in &hist.commit_lens {
        assert!(
            scan.record_ends.contains(&(len as usize)),
            "post-commit length {len} is not a record boundary"
        );
    }

    let crash_dir = tmp("bound-crash");
    let mut cuts = vec![wal::WAL_MAGIC.len()];
    cuts.extend(scan.record_ends.iter().copied());
    for cut in cuts {
        install_crash_image(&crash_dir, &hist.log[..cut], None);
        let want = expected_epoch(cut as u64, &hist.commit_lens);
        let (sess, report) =
            recover(&crash_dir, d, 1).unwrap_or_else(|e| panic!("cut at byte {cut}: {e}"));
        assert_eq!(report.epoch, want, "cut at byte {cut}");
        assert_eq!(digest(&sess), oracle[want as usize], "cut at byte {cut}");
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

#[test]
fn mid_record_tears_recover_to_the_last_intact_epoch() {
    let d = 1;
    let script = churn_script(0x7EA4, d, 4, 6);
    let oracle = oracle_digests(d, &script);
    let dir = tmp("tear");
    let hist = record_history(&dir, d, 1, u64::MAX, &script);

    let scan = wal::scan_log(&hist.log);
    let mut bounds = vec![wal::WAL_MAGIC.len()];
    bounds.extend(scan.record_ends.iter().copied());
    let crash_dir = tmp("tear-crash");
    // A torn magic is also just a short durable prefix.
    install_crash_image(&crash_dir, &hist.log[..4], None);
    let (sess, report) = recover(&crash_dir, d, 1).expect("torn magic");
    assert_eq!(report.epoch, 0);
    assert_eq!(digest(&sess), oracle[0]);
    for window in bounds.windows(2) {
        let (start, end) = (window[0], window[1]);
        for cut in [start + 1, start + (end - start) / 2, end - 1] {
            if cut <= start || cut >= end {
                continue;
            }
            install_crash_image(&crash_dir, &hist.log[..cut], None);
            let want = expected_epoch(cut as u64, &hist.commit_lens);
            let (sess, report) =
                recover(&crash_dir, d, 1).unwrap_or_else(|e| panic!("tear at byte {cut}: {e}"));
            assert_eq!(report.epoch, want, "tear at byte {cut}");
            assert!(report.tail_bytes > 0, "tear at byte {cut} discarded nothing");
            assert_eq!(digest(&sess), oracle[want as usize], "tear at byte {cut}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

#[test]
fn single_bit_flips_never_panic_and_never_surface_a_partial_epoch() {
    let d = 1;
    let script = churn_script(0xF11B, d, 5, 8);
    let oracle = oracle_digests(d, &script);
    let dir = tmp("flip");
    let hist = record_history(&dir, d, 1, u64::MAX, &script);

    let mut rng = Rng::new(0xB17F);
    let mut offsets: Vec<usize> =
        (0..40).map(|_| rng.below(hist.log.len() as u64) as usize).collect();
    offsets.push(0); // magic: the whole log becomes a discarded tail
    offsets.push(hist.log.len() - 1); // final marker's CRC
    let crash_dir = tmp("flip-crash");
    for off in offsets {
        let mut mutated = hist.log.clone();
        let bit = rng.below(8) as u8;
        mutated[off] ^= 1 << bit;
        install_crash_image(&crash_dir, &mutated, None);
        // Every record ending at or before the flip is untouched; the
        // record containing it fails its CRC, so the scan stops there.
        let want = expected_epoch(off as u64, &hist.commit_lens);
        let (sess, report) = recover(&crash_dir, d, 1)
            .unwrap_or_else(|e| panic!("bit {bit} flipped at byte {off}: {e}"));
        assert_eq!(report.epoch, want, "bit {bit} flipped at byte {off}");
        assert_eq!(digest(&sess), oracle[want as usize], "bit {bit} flipped at byte {off}");
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

#[test]
fn three_dimensional_histories_survive_boundary_and_marker_tear_cuts() {
    let d = 3;
    let script = churn_script(0x3D, d, 4, 6);
    let oracle = oracle_digests(d, &script);
    let dir = tmp("d3");
    let hist = record_history(&dir, d, 1, u64::MAX, &script);
    assert_eq!(hist.live, oracle[script.len()]);

    let crash_dir = tmp("d3-crash");
    for (k, &len) in hist.commit_lens.iter().enumerate() {
        let epoch = k as u64 + 1;
        install_crash_image(&crash_dir, &hist.log[..len as usize], None);
        let (sess, report) =
            recover(&crash_dir, d, 1).unwrap_or_else(|e| panic!("boundary epoch {epoch}: {e}"));
        assert_eq!(report.epoch, epoch);
        assert_eq!(digest(&sess), oracle[epoch as usize]);

        // Tear the marker itself: exactly this epoch is lost, even
        // though every one of its op records landed.
        install_crash_image(&crash_dir, &hist.log[..len as usize - 3], None);
        let (sess, report) = recover(&crash_dir, d, 1)
            .unwrap_or_else(|e| panic!("torn marker epoch {epoch}: {e}"));
        assert_eq!(report.epoch, epoch - 1, "torn marker of epoch {epoch}");
        assert_eq!(digest(&sess), oracle[k], "torn marker of epoch {epoch}");
    }

    // The same 3-d history also recovers into a sharded session.
    install_crash_image(&crash_dir, &hist.log, None);
    let (sess, report) = recover(&crash_dir, d, 3).expect("sharded 3-d recovery");
    assert!(matches!(sess, AnySession::Sharded(_)));
    assert_eq!(report.epoch, script.len() as u64);
    assert_eq!(digest(&sess), oracle[script.len()]);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

#[test]
fn histories_recover_across_session_shapes() {
    let d = 1;
    let script = churn_script(0x54A2D, d, 4, 8);
    let oracle = oracle_digests(d, &script);

    // Recorded unsharded, recovered sharded — at every marker boundary.
    let dir = tmp("shape-single");
    let hist = record_history(&dir, d, 1, u64::MAX, &script);
    let crash_dir = tmp("shape-crash");
    for (k, &len) in hist.commit_lens.iter().enumerate() {
        let epoch = k as u64 + 1;
        install_crash_image(&crash_dir, &hist.log[..len as usize], None);
        let (sess, report) = recover(&crash_dir, d, 3)
            .unwrap_or_else(|e| panic!("sharded recovery at epoch {epoch}: {e}"));
        assert!(matches!(sess, AnySession::Sharded(_)), "shards=3 must recover sharded");
        assert_eq!(report.epoch, epoch);
        assert_eq!(digest(&sess), oracle[epoch as usize], "sharded recovery at epoch {epoch}");
    }

    // Recorded sharded, recovered unsharded.
    let sharded_dir = tmp("shape-sharded");
    let sharded = record_history(&sharded_dir, d, 3, u64::MAX, &script);
    assert_eq!(sharded.live, oracle[script.len()], "sharded run diverged from the oracle");
    let (sess, report) = recover(&sharded_dir, d, 1).expect("unsharded recovery of a sharded log");
    assert!(matches!(sess, AnySession::Single(_)));
    assert_eq!(report.epoch, script.len() as u64);
    assert_eq!(digest(&sess), oracle[script.len()]);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
    std::fs::remove_dir_all(&sharded_dir).ok();
}

#[test]
fn checkpoint_cadence_recovers_snapshot_plus_log_tail() {
    let d = 1;
    let epochs = 7;
    let script = churn_script(0xCADE, d, epochs, 8);
    let oracle = oracle_digests(d, &script);
    let dir = tmp("ckpt");
    // Checkpoint every 2 commits: snapshots at epochs 2, 4 and 6, so
    // the directory ends as a snapshot at 6 plus a log holding epoch 7.
    let hist = record_history(&dir, d, 1, 2, &script);
    assert_eq!(hist.live, oracle[epochs]);
    let snap = hist.snap.as_deref().expect("cadence installed no snapshot");

    let crash_dir = tmp("ckpt-crash");
    let scan = wal::scan_log(&hist.log);
    let last_len = *hist.commit_lens.last().expect("commit lengths");
    let mut cuts = vec![wal::WAL_MAGIC.len()];
    cuts.extend(scan.record_ends.iter().copied());
    for cut in cuts {
        install_crash_image(&crash_dir, &hist.log[..cut], Some(snap));
        let want = if cut as u64 >= last_len { epochs as u64 } else { epochs as u64 - 1 };
        let (sess, report) =
            recover(&crash_dir, d, 1).unwrap_or_else(|e| panic!("cut at byte {cut}: {e}"));
        assert_eq!(report.epoch, want, "cut at byte {cut}");
        assert!(report.snapshot_regions > 0, "cut at byte {cut} ignored the snapshot");
        assert_eq!(digest(&sess), oracle[want as usize], "cut at byte {cut}");
    }

    // The log lost entirely: the snapshot alone carries its epoch.
    let _ = std::fs::remove_dir_all(&crash_dir);
    std::fs::create_dir_all(&crash_dir).expect("create crash dir");
    std::fs::write(crash_dir.join(snapfile::SNAP_FILE), snap).expect("write snapshot");
    let (sess, report) = recover(&crash_dir, d, 1).expect("snapshot-only recovery");
    assert_eq!(report.epoch, epochs as u64 - 1);
    assert_eq!(digest(&sess), oracle[epochs - 1]);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

#[test]
fn recovery_resumes_logging_and_a_second_crash_recovers_the_continuation() {
    let d = 1;
    let epochs = 6;
    let script = churn_script(0x5E5, d, epochs, 8);
    let oracle = oracle_digests(d, &script);
    let dir = tmp("resume");
    let hist = record_history(&dir, d, 1, u64::MAX, &script);

    // Crash right after epoch 3's marker.
    let cut = hist.commit_lens[2] as usize;
    let crash_dir = tmp("resume-crash");
    install_crash_image(&crash_dir, &hist.log[..cut], None);

    // Recovery is idempotent: a second recovery (after the first one
    // checkpointed and truncated the directory) sees the same state.
    let (first, report) = recover(&crash_dir, d, 1).expect("first recovery");
    assert_eq!(report.epoch, 3);
    let at_crash = digest(&first);
    assert_eq!(at_crash, oracle[3]);
    drop(first);
    let (mut resumed, report) = recover(&crash_dir, d, 1).expect("second recovery");
    assert_eq!(report.epoch, 3);
    assert_eq!(digest(&resumed), at_crash);

    // Continue the script where the crash cut it off; the resumed WAL
    // must make the continuation durable too.
    for ops in &script[3..] {
        apply(&mut resumed, ops);
        resumed.commit();
    }
    assert_eq!(resumed.wal_error(), None, "resumed WAL degraded");
    assert_eq!(digest(&resumed), oracle[epochs]);
    drop(resumed);

    let (reborn, report) = recover(&crash_dir, d, 1).expect("recovery of the continuation");
    assert_eq!(report.epoch, epochs as u64);
    assert_eq!(digest(&reborn), oracle[epochs]);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

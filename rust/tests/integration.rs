//! Cross-module integration tests: engines × workloads × service ×
//! XLA backend. (Unit tests live in each module; these exercise the
//! composed system through the public `DdmEngine` API.)

use std::sync::Arc;

use ddm::algos::Algo;
use ddm::engine::DdmEngine;
use ddm::exec::ThreadPool;
use ddm::hla::{RegionKind, RegionSpec, RoutingSpace};
use ddm::prng::Rng;
use ddm::sets::SetImpl;
use ddm::workload::koln::{koln_workload, KolnParams};
use ddm::workload::{alpha_workload, clustered_workload, AlphaParams};

fn engine_on(pool: &Arc<ThreadPool>, algo: Algo, p: usize) -> DdmEngine {
    DdmEngine::builder()
        .algo(algo)
        .threads(p)
        .ncells(128)
        .set_impl(SetImpl::Bit)
        .pool(Arc::clone(pool))
        .build()
}

/// Every algorithm × every workload family × several thread counts
/// produce the identical pair set through the engine API.
#[test]
fn all_engines_agree_across_workloads() {
    let pool = Arc::new(ThreadPool::new(7));
    let ap = AlphaParams {
        n_total: 3_000,
        alpha: 10.0,
        space: 1e5,
    };
    let workloads: Vec<(&str, _)> = vec![
        ("uniform", alpha_workload(31, &ap)),
        ("clustered", clustered_workload(32, &ap, 4, 800.0)),
        (
            "koln",
            koln_workload(33, &KolnParams::default().scaled(0.003)),
        ),
    ];
    for (name, (subs, upds)) in workloads {
        let reference = engine_on(&pool, Algo::Bfm, 1).pairs_1d(&subs, &upds);
        for algo in Algo::ALL {
            for p in [1, 3, 8] {
                let got = engine_on(&pool, algo, p).pairs_1d(&subs, &upds);
                assert_eq!(
                    got,
                    reference,
                    "{name}/{}/P={p} disagrees with BFM",
                    algo.name()
                );
            }
        }
        // The adaptive engine agrees too.
        let auto = DdmEngine::builder()
            .auto()
            .threads(4)
            .pool(Arc::clone(&pool))
            .build();
        assert_eq!(auto.pairs_1d(&subs, &upds), reference, "{name}/auto");
    }
}

/// The engine's d-dimensional path with each parallel 1-D matcher
/// equals the direct d-rectangle check.
#[test]
fn ddim_reduction_with_every_engine() {
    let pool = Arc::new(ThreadPool::new(3));
    let mut rng = Rng::new(0x1717);
    for d in [2usize, 3] {
        let mut subs = ddm::core::RegionsNd::new(d);
        let mut upds = ddm::core::RegionsNd::new(d);
        for _ in 0..150 {
            let rect: Vec<ddm::core::Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 100.0);
                    ddm::core::Interval::new(lo, lo + rng.uniform(0.0, 15.0))
                })
                .collect();
            subs.push(&rect);
        }
        for _ in 0..120 {
            let rect: Vec<ddm::core::Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 100.0);
                    ddm::core::Interval::new(lo, lo + rng.uniform(0.0, 15.0))
                })
                .collect();
            upds.push(&rect);
        }
        let mut want = Vec::new();
        for i in 0..subs.len() {
            for j in 0..upds.len() {
                if subs.rects_intersect(i, &upds, j) {
                    want.push((i as u32, j as u32));
                }
            }
        }
        for algo in [Algo::Psbm, Algo::Itm, Algo::Gbm] {
            let engine = engine_on(&pool, algo, 4);
            assert_eq!(
                engine.pairs_nd(&subs, &upds),
                want,
                "d={d} algo={}",
                algo.name()
            );
            assert_eq!(engine.count_nd(&subs, &upds), want.len() as u64);
        }
    }
}

/// Service end-to-end: Fig. 1 style scenario — registrations, full
/// match, publish/poll routing, dynamic moves — all consistent, on an
/// injected engine.
#[test]
fn service_scenario_consistency() {
    type Handles = (Vec<ddm::hla::RegionHandle>, Vec<ddm::hla::RegionHandle>);

    // Deterministic state construction, replayable on any service.
    fn build_state(svc: &mut ddm::hla::DdmService) -> (ddm::hla::FederateId, Handles) {
        let fed_a = svc.join("a");
        let fed_b = svc.join("b");
        let mut rng = Rng::new(0x5E5E);
        let mut subs = Vec::new();
        for _ in 0..200 {
            let x = rng.below(99_000);
            subs.push(
                svc.register(
                    fed_a,
                    RegionKind::Subscription,
                    &RegionSpec::interval(x, x + 500),
                )
                .unwrap(),
            );
        }
        let mut upds = Vec::new();
        for _ in 0..100 {
            let x = rng.below(99_000);
            upds.push(
                svc.register(fed_b, RegionKind::Update, &RegionSpec::interval(x, x + 300))
                    .unwrap(),
            );
        }
        (fed_a, (subs, upds))
    }

    fn move_half(svc: &mut ddm::hla::DdmService, subs: &[ddm::hla::RegionHandle]) {
        let mut rng = Rng::new(0x5E5F);
        for &s in subs.iter().take(50) {
            let x = rng.below(99_000);
            svc.modify(s, &RegionSpec::interval(x, x + 500)).unwrap();
        }
    }

    let mut svc = ddm::hla::DdmService::with_engine(
        RoutingSpace::uniform(1, 100_000),
        DdmEngine::builder().algo(Algo::Psbm).threads(4).build(),
    );
    let (fed_a, (subs, upds)) = build_state(&mut svc);
    let pairs = svc.match_all();

    // Publishing every update must deliver exactly the matched pairs.
    let mut delivered = 0;
    for &u in &upds {
        delivered += svc.publish(u, 1).unwrap();
    }
    assert_eq!(delivered, pairs.len());
    assert_eq!(svc.poll(fed_a).len(), delivered);

    // Dynamic: move subscriptions; a service on a *different* engine,
    // fed the same state, agrees (swapping = builder change only).
    move_half(&mut svc, &subs);
    let mut pairs2 = svc.match_all();

    let mut svc_itm = ddm::hla::DdmService::with_engine(
        RoutingSpace::uniform(1, 100_000),
        DdmEngine::builder().algo(Algo::Itm).threads(2).build(),
    );
    let (_, (subs2, _)) = build_state(&mut svc_itm);
    move_half(&mut svc_itm, &subs2);
    let mut pairs3 = svc_itm.match_all();

    let norm = |v: &mut Vec<(ddm::hla::RegionHandle, ddm::hla::RegionHandle)>| {
        v.sort_by_key(|(a, b)| (a.id, b.id));
    };
    norm(&mut pairs2);
    norm(&mut pairs3);
    assert!(!pairs2.is_empty());
    assert_eq!(pairs2, pairs3);
}

/// XLA backend agrees with native matching on service-shaped data
/// (skips unless built with `--features xla` and `make artifacts` ran).
#[test]
fn xla_backend_matches_native_on_service_regions() {
    let dir = std::path::Path::new(ddm::runtime::DEFAULT_ARTIFACT_DIR);
    if !ddm::runtime::artifacts_available(dir) {
        eprintln!("skipping: xla feature off or artifacts not built");
        return;
    }
    let be = ddm::runtime::XlaMatchBackend::load(dir).expect("backend");
    let mut rng = Rng::new(0xCAFE);
    // Integer (HLA-style) coordinates are f32-exact below 2^24.
    let mut subs = ddm::core::Regions1D::default();
    let mut upds = ddm::core::Regions1D::default();
    for _ in 0..500 {
        let x = rng.below(1_000_000) as f64;
        subs.push(ddm::core::Interval::new(x, x + 1000.0));
    }
    for _ in 0..700 {
        let x = rng.below(1_000_000) as f64;
        upds.push(ddm::core::Interval::new(x, x + 800.0));
    }
    let native = DdmEngine::builder().algo(Algo::Psbm).threads(4).build();
    let k_native = native.count_1d(&subs, &upds);
    let k_xla = be.match_counts_1d(&subs, &upds).expect("xla count");
    assert_eq!(k_native, k_xla);

    let pairs_native = native.pairs_1d(&subs, &upds);
    let mut pairs_xla = be.match_pairs_1d(&subs, &upds).expect("xla pairs");
    pairs_xla.sort_unstable();
    assert_eq!(pairs_native, pairs_xla);
}

/// Coordinator smoke: concurrent clients against one service loop.
#[test]
fn coordinator_handles_concurrent_clients() {
    use ddm::coordinator::{Coordinator, CoordinatorConfig};
    let coord = Coordinator::spawn(CoordinatorConfig::new(
        RoutingSpace::uniform(1, 1_000_000),
        DdmEngine::builder().threads(2).build(),
    ));
    let c = coord.client();
    let fed = c.join("shared");
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let c = coord.client();
            s.spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..50 {
                    let x = rng.below(990_000);
                    let h = c
                        .register(
                            fed,
                            RegionKind::Subscription,
                            RegionSpec::interval(x, x + 100),
                        )
                        .unwrap();
                    c.modify(h, RegionSpec::interval(x, x + 200)).unwrap();
                }
            });
        }
    });
    let m = c.metrics();
    assert_eq!(m.counter("registers"), 200);
    assert_eq!(m.counter("modifies"), 200);
    let metrics = coord.shutdown();
    assert_eq!(metrics.counter("registers"), 200);
}

/// Session-vs-static equivalence (the DdmSession acceptance property):
/// after ANY op sequence, accumulating the epochs' `MatchDiff`s
/// reproduces exactly the pair set of a fresh `pairs_nd` over the same
/// live regions — checked for two algorithms and d ∈ {1, 3}, with
/// eager batching and forced parallel apply in the mix.
#[test]
fn session_diffs_reproduce_static_matching() {
    use ddm::core::{Interval, RegionsNd};
    use std::collections::{BTreeMap, HashSet};

    let pool = Arc::new(ThreadPool::new(3));
    for &algo in &[Algo::Psbm, Algo::Itm] {
        for d in [1usize, 3] {
            let engine = DdmEngine::builder()
                .algo(algo)
                .threads(3)
                .pool(Arc::clone(&pool))
                .parallel_cutoff(8)
                .batch_threshold(16) // fires twice per 40-op epoch
                .build();
            let mut sess = engine.session(d);
            let mut rng = Rng::new(0x5E55 + d as u64);
            let mut model_s: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
            let mut model_u: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
            let mut live: HashSet<(u32, u32)> = HashSet::new();
            for epoch in 0..12 {
                for _ in 0..40 {
                    let key = rng.below(60) as u32;
                    let sub_side = rng.chance(0.5);
                    if rng.chance(0.8) {
                        let rect: Vec<Interval> = (0..d)
                            .map(|_| {
                                let lo = rng.uniform(0.0, 90.0);
                                Interval::new(lo, lo + rng.uniform(0.5, 12.0))
                            })
                            .collect();
                        if sub_side {
                            sess.upsert_subscription(key, &rect);
                            model_s.insert(key, rect);
                        } else {
                            sess.upsert_update(key, &rect);
                            model_u.insert(key, rect);
                        }
                    } else if sub_side {
                        sess.remove_subscription(key);
                        model_s.remove(&key);
                    } else {
                        sess.remove_update(key);
                        model_u.remove(&key);
                    }
                }
                let diff = sess.commit();
                for &(s, u) in &diff.removed {
                    assert!(live.remove(&(s, u)), "removed non-live pair");
                }
                for &(s, u) in &diff.added {
                    assert!(live.insert((s, u)), "added already-live pair");
                }
                // Fresh static match over the same live regions.
                let mut subs = RegionsNd::new(d);
                let mut skeys = Vec::new();
                for (&k, rect) in &model_s {
                    subs.push(rect);
                    skeys.push(k);
                }
                let mut upds = RegionsNd::new(d);
                let mut ukeys = Vec::new();
                for (&k, rect) in &model_u {
                    upds.push(rect);
                    ukeys.push(k);
                }
                if subs.is_empty() || upds.is_empty() {
                    assert!(live.is_empty());
                    continue;
                }
                let want: HashSet<(u32, u32)> = engine
                    .pairs_nd(&subs, &upds)
                    .into_iter()
                    .map(|(si, uj)| (skeys[si as usize], ukeys[uj as usize]))
                    .collect();
                assert_eq!(live, want, "algo={} d={d} epoch={epoch}", algo.name());
                // The retained pair set agrees with the accumulation too.
                let mut acc: Vec<(u32, u32)> = live.iter().copied().collect();
                acc.sort_unstable();
                assert_eq!(sess.pairs(), acc);
            }
        }
    }
}

/// Sharded-session equivalence (the sharding acceptance property):
/// across shards ∈ {1, 2, 7} and d ∈ {1, 3}, with regions wider than
/// one stripe and upserts relocating regions across stripe boundaries,
/// the `ShardedSession` produces per-epoch diffs identical to the
/// unsharded `DdmSession`, and the accumulated diffs reproduce exactly
/// a fresh static `pairs_nd` over the live regions.
#[test]
fn sharded_session_equivalence_property() {
    use ddm::core::{Interval, RegionsNd};
    use ddm::shard::SpacePartitioner;
    use std::collections::{BTreeMap, HashSet};

    let pool = Arc::new(ThreadPool::new(3));
    let engine = DdmEngine::builder()
        .threads(3)
        .parallel_cutoff(8)
        .pool(Arc::clone(&pool))
        .build();
    for d in [1usize, 3] {
        for shards in [1usize, 2, 7] {
            // Stripes over [0, 100): width 100/7 ≈ 14, so the wide
            // extents below span several stripes.
            let part = SpacePartitioner::uniform(shards, 0, Interval::new(0.0, 100.0));
            let mut sh = engine.sharded_session_with(d, part);
            let mut un = engine.session(d);
            let mut model_s: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
            let mut model_u: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
            let mut live: HashSet<(u32, u32)> = HashSet::new();
            let mut rng = Rng::new(0x5A4D + d as u64 * 31 + shards as u64);
            for epoch in 0..10 {
                for _ in 0..40 {
                    let key = rng.below(50) as u32;
                    let sub_side = rng.chance(0.5);
                    if rng.chance(0.85) {
                        // Upserting an existing key relocates it to a
                        // fresh uniform position — boundary crossings
                        // happen constantly.
                        let rect: Vec<Interval> = (0..d)
                            .map(|k| {
                                let lo = rng.uniform(0.0, 95.0);
                                let len = if k == 0 && rng.chance(0.35) {
                                    rng.uniform(20.0, 80.0) // wider than a stripe
                                } else {
                                    rng.uniform(0.5, 10.0)
                                };
                                Interval::new(lo, lo + len)
                            })
                            .collect();
                        if sub_side {
                            sh.upsert_subscription(key, &rect);
                            un.upsert_subscription(key, &rect);
                            model_s.insert(key, rect);
                        } else {
                            sh.upsert_update(key, &rect);
                            un.upsert_update(key, &rect);
                            model_u.insert(key, rect);
                        }
                    } else if sub_side {
                        sh.remove_subscription(key);
                        un.remove_subscription(key);
                        model_s.remove(&key);
                    } else {
                        sh.remove_update(key);
                        un.remove_update(key);
                        model_u.remove(&key);
                    }
                }
                let (ds, du) = (sh.commit(), un.commit());
                assert_eq!(ds, du, "d={d} shards={shards} epoch={epoch}");
                for &(s, u) in &ds.removed {
                    assert!(live.remove(&(s, u)), "removed non-live pair");
                }
                for &(s, u) in &ds.added {
                    assert!(live.insert((s, u)), "added already-live pair");
                }
                // Fresh static match over the same live regions.
                let mut subs = RegionsNd::new(d);
                let mut skeys = Vec::new();
                for (&k, rect) in &model_s {
                    subs.push(rect);
                    skeys.push(k);
                }
                let mut upds = RegionsNd::new(d);
                let mut ukeys = Vec::new();
                for (&k, rect) in &model_u {
                    upds.push(rect);
                    ukeys.push(k);
                }
                let mut want: Vec<(u32, u32)> = if subs.is_empty() || upds.is_empty() {
                    Vec::new()
                } else {
                    engine
                        .pairs_nd(&subs, &upds)
                        .into_iter()
                        .map(|(si, uj)| (skeys[si as usize], ukeys[uj as usize]))
                        .collect()
                };
                want.sort_unstable();
                let mut acc: Vec<(u32, u32)> = live.iter().copied().collect();
                acc.sort_unstable();
                assert_eq!(acc, want, "d={d} shards={shards} epoch={epoch}");
                assert_eq!(sh.pairs(), want, "retained sharded pair set");
                assert_eq!(sh.n_pairs(), want.len());
            }
        }
    }
}

/// MVCC immutability property (the snapshot acceptance property): an
/// `EpochSnapshot` taken at epoch e answers identically — epoch, pair
/// set, point lookups, and per-key indexes — after every subsequent
/// commit and after the session itself is dropped; and at every epoch
/// the freshly published snapshot equals both a live read and a fresh
/// static `pairs_nd` over the same regions. Runs across sharded and
/// unsharded sessions, d ∈ {1, 3}, P ∈ {1, 4}.
#[test]
fn epoch_snapshots_are_immutable_and_match_static_state() {
    use ddm::core::{Interval, RegionsNd};
    use ddm::session::EpochSnapshot;
    use ddm::shard::{AnySession, SpacePartitioner};
    use std::collections::BTreeMap;

    const KEYS: u32 = 48;
    type Fingerprint = (u64, Vec<(u32, u32)>, Vec<Vec<u32>>, Vec<Vec<u32>>);
    let fingerprint = |snap: &EpochSnapshot| -> Fingerprint {
        (
            snap.epoch(),
            snap.pairs(),
            (0..KEYS).map(|k| snap.updates_of(k)).collect(),
            (0..KEYS).map(|k| snap.subscriptions_of(k)).collect(),
        )
    };

    for p in [1usize, 4] {
        let engine = DdmEngine::builder().threads(p).parallel_cutoff(8).build();
        for d in [1usize, 3] {
            for shards in [0usize, 4] {
                let label = format!("P={p} d={d} shards={shards}");
                let mut sess = if shards == 0 {
                    AnySession::Single(engine.session(d))
                } else {
                    let part =
                        SpacePartitioner::uniform(shards, 0, Interval::new(0.0, 100.0));
                    AnySession::Sharded(engine.sharded_session_with(d, part))
                };
                let mut rng = Rng::new(
                    0xE90C ^ (d as u64 * 31) ^ (shards as u64 * 7) ^ ((p as u64) << 9),
                );
                let mut model_s: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
                let mut model_u: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
                // Every epoch's snapshot, kept pinned with its
                // fingerprint taken at publish time.
                let mut kept: Vec<(EpochSnapshot, Fingerprint)> = Vec::new();
                for epoch in 0..6 {
                    for _ in 0..30 {
                        let key = rng.below(KEYS as u64) as u32;
                        let sub_side = rng.chance(0.5);
                        if rng.chance(0.85) {
                            let rect: Vec<Interval> = (0..d)
                                .map(|_| {
                                    let lo = rng.uniform(0.0, 90.0);
                                    Interval::new(lo, lo + rng.uniform(0.5, 25.0))
                                })
                                .collect();
                            if sub_side {
                                sess.upsert_subscription(key, &rect);
                                model_s.insert(key, rect);
                            } else {
                                sess.upsert_update(key, &rect);
                                model_u.insert(key, rect);
                            }
                        } else if sub_side {
                            sess.remove_subscription(key);
                            model_s.remove(&key);
                        } else {
                            sess.remove_update(key);
                            model_u.remove(&key);
                        }
                    }
                    let _ = sess.commit();
                    let snap = sess.snapshot();
                    assert_eq!(snap.epoch(), sess.epoch(), "{label} epoch {epoch}");
                    assert_eq!(
                        snap.pairs(),
                        sess.pairs(),
                        "{label} epoch {epoch}: snapshot != live"
                    );

                    // Fresh static match over the same live regions.
                    let mut subs = RegionsNd::new(d);
                    let mut skeys = Vec::new();
                    for (&k, rect) in &model_s {
                        subs.push(rect);
                        skeys.push(k);
                    }
                    let mut upds = RegionsNd::new(d);
                    let mut ukeys = Vec::new();
                    for (&k, rect) in &model_u {
                        upds.push(rect);
                        ukeys.push(k);
                    }
                    let mut want: Vec<(u32, u32)> = if subs.is_empty() || upds.is_empty() {
                        Vec::new()
                    } else {
                        engine
                            .pairs_nd(&subs, &upds)
                            .into_iter()
                            .map(|(si, uj)| (skeys[si as usize], ukeys[uj as usize]))
                            .collect()
                    };
                    want.sort_unstable();
                    assert_eq!(
                        snap.pairs(),
                        want,
                        "{label} epoch {epoch}: snapshot != fresh static match"
                    );

                    // Every previously taken snapshot must still answer
                    // bit-identically despite this commit.
                    for (old, fp) in &kept {
                        assert_eq!(&fingerprint(old), fp, "{label}: pinned snapshot mutated");
                    }
                    let fp = fingerprint(&snap);
                    kept.push((snap, fp));
                }
                // The snapshots outlive the session itself.
                drop(sess);
                for (old, fp) in &kept {
                    assert_eq!(
                        &fingerprint(old),
                        fp,
                        "{label}: snapshot changed after session drop"
                    );
                }
            }
        }
    }
}

/// N-D equivalence property suite (the native-pipeline acceptance
/// property): the native sweep-and-verify path, the per-dimension
/// reduction and a brute-force d-rectangle oracle produce the
/// identical pair set for EVERY matcher × d ∈ {2, 3, 5} × thread
/// count, on workloads salted with zero-width and boundary-touching
/// rectangles (integer lattice coordinates make touching exact).
#[test]
fn nd_native_reduction_and_oracle_agree_for_every_matcher() {
    use ddm::core::{Interval, RegionsNd};
    use ddm::engine::{NdMode, SweepDim};

    let pool = Arc::new(ThreadPool::new(3));
    let mut rng = Rng::new(0x4D4D);
    for d in [2usize, 3, 5] {
        let mut rects = |count: usize| -> RegionsNd {
            let mut out = RegionsNd::new(d);
            for _ in 0..count {
                let rect: Vec<Interval> = (0..d)
                    .map(|_| {
                        let lo = rng.below(30) as f64;
                        // len 0 (zero-width) through 3; integer lattice
                        // ⇒ touching endpoints are exact, not ε-away.
                        let len = rng.below(4) as f64;
                        Interval::new(lo, lo + len)
                    })
                    .collect();
                out.push(&rect);
            }
            out
        };
        let subs = rects(100);
        let upds = rects(90);
        let mut want = Vec::new();
        for i in 0..subs.len() {
            for j in 0..upds.len() {
                if subs.rects_intersect(i, &upds, j) {
                    want.push((i as u32, j as u32));
                }
            }
        }
        assert!(!want.is_empty(), "d={d} oracle should not be empty");

        for algo in Algo::ALL {
            for p in [1usize, 2, 4] {
                for mode in [NdMode::Native, NdMode::Reduction] {
                    let engine = DdmEngine::builder()
                        .algo(algo)
                        .threads(p)
                        .ncells(64)
                        .nd_mode(mode)
                        .pool(Arc::clone(&pool))
                        .build();
                    let label = format!("{}/d={d}/P={p}/{mode:?}", algo.name());
                    assert_eq!(engine.pairs_nd(&subs, &upds), want, "{label}");
                    assert_eq!(engine.count_nd(&subs, &upds), want.len() as u64, "{label}");
                }
            }
        }
        // Pinning the sweep to ANY dimension must not change the set.
        for k in 0..d {
            let engine = DdmEngine::builder()
                .algo(Algo::Psbm)
                .threads(3)
                .sweep_dim(SweepDim::Fixed(k))
                .pool(Arc::clone(&pool))
                .build();
            assert_eq!(engine.pairs_nd(&subs, &upds), want, "d={d} sweep={k}");
        }
        // The sharded static wrapper composes with both modes.
        for mode in [NdMode::Native, NdMode::Reduction] {
            let engine = DdmEngine::builder()
                .algo(Algo::Psbm)
                .threads(3)
                .shards(4)
                .nd_mode(mode)
                .pool(Arc::clone(&pool))
                .build();
            assert_eq!(engine.pairs_nd(&subs, &upds), want, "sharded d={d} {mode:?}");
            assert_eq!(engine.count_nd(&subs, &upds), want.len() as u64);
        }
    }
}

/// Session and sharded-session end states in d = 5 equal a fresh
/// static `pairs_nd` through BOTH N-D modes (the incremental paths
/// must agree with whatever the static pipeline computes).
#[test]
fn session_and_sharded_nd_end_state_equals_static_nd() {
    use ddm::core::{Interval, RegionsNd};
    use ddm::engine::NdMode;
    use ddm::shard::SpacePartitioner;
    use std::collections::BTreeMap;

    let d = 5usize;
    let engine = DdmEngine::builder().threads(3).parallel_cutoff(8).build();
    let part = SpacePartitioner::uniform(3, 0, Interval::new(0.0, 100.0));
    let mut sess = engine.session(d);
    let mut sharded = engine.sharded_session_with(d, part);
    let mut model_s: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
    let mut model_u: BTreeMap<u32, Vec<Interval>> = BTreeMap::new();
    let mut rng = Rng::new(0x4D5D);
    for _epoch in 0..4 {
        for _ in 0..60 {
            let key = rng.below(40) as u32;
            let rect: Vec<Interval> = (0..d)
                .map(|k| {
                    let lo = rng.uniform(0.0, 90.0);
                    // Dimension 2 barely discriminates — the session's
                    // recompute seed must route around it.
                    let len = if k == 2 { 60.0 } else { rng.uniform(0.5, 8.0) };
                    Interval::new(lo, lo + len)
                })
                .collect();
            match rng.below(4) {
                0 | 1 => {
                    sess.upsert_subscription(key, &rect);
                    sharded.upsert_subscription(key, &rect);
                    model_s.insert(key, rect);
                }
                2 => {
                    sess.upsert_update(key, &rect);
                    sharded.upsert_update(key, &rect);
                    model_u.insert(key, rect);
                }
                _ => {
                    sess.remove_update(key);
                    sharded.remove_update(key);
                    model_u.remove(&key);
                }
            }
        }
        sess.commit();
        sharded.commit();

        let mut subs = RegionsNd::new(d);
        let mut skeys = Vec::new();
        for (&k, rect) in &model_s {
            subs.push(rect);
            skeys.push(k);
        }
        let mut upds = RegionsNd::new(d);
        let mut ukeys = Vec::new();
        for (&k, rect) in &model_u {
            upds.push(rect);
            ukeys.push(k);
        }
        if subs.is_empty() || upds.is_empty() {
            assert!(sess.pairs().is_empty());
            continue;
        }
        for mode in [NdMode::Native, NdMode::Reduction] {
            let static_engine = DdmEngine::builder().threads(2).nd_mode(mode).build();
            let mut want: Vec<(u32, u32)> = static_engine
                .pairs_nd(&subs, &upds)
                .into_iter()
                .map(|(si, uj)| (skeys[si as usize], ukeys[uj as usize]))
                .collect();
            want.sort_unstable();
            assert_eq!(sess.pairs(), want, "session vs static {mode:?}");
            assert_eq!(sharded.pairs(), want, "sharded vs static {mode:?}");
        }
    }
}

/// Thread-count invariance under the engine API (heavier than the
/// per-module variants: full workload, many P values, shared pool).
#[test]
fn psbm_thread_invariance_heavy() {
    let pool = Arc::new(ThreadPool::new(15));
    let ap = AlphaParams {
        n_total: 10_000,
        alpha: 100.0,
        space: 1e6,
    };
    let (subs, upds) = alpha_workload(77, &ap);
    let base = DdmEngine::builder()
        .algo(Algo::Psbm)
        .threads(1)
        .pool(Arc::clone(&pool))
        .build();
    let want = base.pairs_1d(&subs, &upds);
    for p in 2..=16 {
        let got = base.with_threads(p).pairs_1d(&subs, &upds);
        assert_eq!(got.len(), want.len(), "P={p}");
        assert_eq!(got, want, "P={p}");
    }
}

/// Scratch-reuse equivalence (the zero-allocation hot path's safety
/// net): two consecutive `match_nd` calls on ONE engine — whose
/// second call reuses the first call's `MatchScratch` buffers — must
/// produce bit-identical pair sets to fresh-allocation runs, across
/// SBM/PSBM/GBM × d∈{1,3} × both sort implementations; and the
/// scratch must stop growing after the first call. The session
/// variant (3 epochs, warm vs cold scratch) lives in
/// `session::tests::scratch_reuse_matches_cold_sessions_and_stops_growing`.
#[test]
fn scratch_reuse_is_bit_identical_to_fresh_allocation() {
    use ddm::core::{Interval, RegionsNd};
    use ddm::exec::SortAlgo;

    let pool = Arc::new(ThreadPool::new(3));
    let mut rng = Rng::new(0x5C4A7C4);
    for d in [1usize, 3] {
        let mut subs = RegionsNd::new(d);
        let mut upds = RegionsNd::new(d);
        for _ in 0..700 {
            let rect: Vec<Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 200.0);
                    Interval::new(lo, lo + rng.uniform(0.0, 15.0))
                })
                .collect();
            subs.push(&rect);
            let rect: Vec<Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 200.0);
                    Interval::new(lo, lo + rng.uniform(0.0, 15.0))
                })
                .collect();
            upds.push(&rect);
        }
        for algo in [Algo::Sbm, Algo::Psbm, Algo::Gbm] {
            for sort in [SortAlgo::Radix, SortAlgo::Merge] {
                let reused = DdmEngine::builder()
                    .algo(algo)
                    .threads(4)
                    .ncells(64)
                    .sort_algo(sort)
                    .pool(Arc::clone(&pool))
                    .build();
                // Fresh engine per call = fresh scratch per call.
                let fresh = || {
                    DdmEngine::builder()
                        .algo(algo)
                        .threads(4)
                        .ncells(64)
                        .sort_algo(sort)
                        .pool(Arc::clone(&pool))
                        .build()
                        .pairs_nd(&subs, &upds)
                };
                let want = fresh();
                assert!(!want.is_empty());
                let first = reused.pairs_nd(&subs, &upds);
                assert_eq!(first, want, "{algo:?} d={d} {sort:?} cold call");
                let stats = reused.scratch_stats();
                for call in 0..2 {
                    let warm = reused.pairs_nd(&subs, &upds);
                    assert_eq!(warm, want, "{algo:?} d={d} {sort:?} warm call {call}");
                    assert_eq!(
                        reused.scratch_stats(),
                        stats,
                        "{algo:?} d={d} {sort:?} scratch grew on warm call {call}"
                    );
                    assert_eq!(reused.count_nd(&subs, &upds), want.len() as u64);
                }
                assert_eq!(fresh(), want, "fresh run after reuse");
            }
        }
    }
}

/// The `--sort` A/B seam: radix and merge engines agree with each
/// other and with brute force on every workload family.
#[test]
fn radix_and_merge_engines_agree_end_to_end() {
    use ddm::exec::SortAlgo;

    let pool = Arc::new(ThreadPool::new(3));
    let ap = AlphaParams {
        n_total: 4_000,
        alpha: 50.0,
        space: 1e5,
    };
    let (subs, upds) = alpha_workload(0x50AB, &ap);
    let bfm = engine_on(&pool, Algo::Bfm, 1);
    let want = bfm.pairs_1d(&subs, &upds);
    for algo in [Algo::Sbm, Algo::Psbm] {
        let mut per_sort = Vec::new();
        for sort in [SortAlgo::Radix, SortAlgo::Merge] {
            let e = DdmEngine::builder()
                .algo(algo)
                .threads(4)
                .sort_algo(sort)
                .pool(Arc::clone(&pool))
                .build();
            let got = e.pairs_1d(&subs, &upds);
            assert_eq!(got, want, "{algo:?} {sort:?} vs brute force");
            assert_eq!(e.count_1d(&subs, &upds), want.len() as u64);
            per_sort.push(got);
        }
        assert_eq!(per_sort[0], per_sort[1], "{algo:?} radix vs merge");
    }
}

/// Observability acceptance: with tracing on, the per-commit `commit`
/// envelope spans must tile the wall-clock measured around each
/// `commit()` call to within 10% (the envelope opens on commit's
/// first statement and closes on its last, so it only undershoots by
/// call overhead) — and with tracing off (the default), commits must
/// record nothing at all.
#[test]
fn traced_commit_envelopes_cover_commit_wall() {
    use ddm::obs::{phase_totals, Phase};
    use ddm::workload::churn::{relocate, MoveScript};
    use std::time::Instant;

    let pool = Arc::new(ThreadPool::new(3));
    let ap = AlphaParams {
        n_total: 20_000,
        alpha: 100.0,
        space: 1e6,
    };
    let (mut subs, mut upds) = alpha_workload(0x0B5ACC, &ap);
    let space_hi = ap.space;
    let epochs = 4usize;

    let engine = DdmEngine::builder()
        .threads(3)
        .pool(Arc::clone(&pool))
        .trace(true)
        .build();
    let mut sess = engine.session(1);
    assert!(sess.trace_enabled());
    sess.load_dense_1d(&subs, &upds);

    let mut script = MoveScript::new(0xC0B5);
    let mut spans = Vec::new();
    let mut wall = 0.0f64;
    for epoch in 0..=epochs {
        if epoch > 0 {
            for _ in 0..1_000 {
                let (sub_side, idx, frac) = script.next(subs.len(), upds.len());
                if sub_side {
                    let iv = relocate(&mut subs, idx, frac, space_hi);
                    sess.upsert_subscription(idx as u32, &[iv]);
                } else {
                    let iv = relocate(&mut upds, idx, frac, space_hi);
                    sess.upsert_update(idx as u32, &[iv]);
                }
            }
        }
        let t0 = Instant::now();
        sess.commit();
        wall += t0.elapsed().as_secs_f64();
        spans.extend(sess.drain_trace());
    }
    assert_eq!(sess.trace_dropped(), 0, "span ring buffers overflowed");
    assert!(sess.drain_trace().is_empty(), "drain_trace must drain");

    let totals = phase_totals(&spans);
    let (env_ns, env_count) = totals
        .iter()
        .find(|&&(p, ..)| p == Phase::Commit.id())
        .map_or((0, 0), |&(_, ns, count, _)| (ns, count));
    assert_eq!(
        env_count,
        (epochs + 1) as u64,
        "one commit envelope per commit() call"
    );
    assert!(
        totals.len() >= 3,
        "expected interior phases besides the envelope, got {totals:?}"
    );

    let env_s = env_ns as f64 / 1e9;
    assert!(
        env_s >= wall * 0.90,
        "commit envelopes ({env_s:.6}s) cover <90% of commit wall ({wall:.6}s)"
    );
    assert!(
        env_s <= wall * 1.02,
        "commit envelopes ({env_s:.6}s) exceed commit wall ({wall:.6}s)"
    );

    // Tracing off (the default): same workload, zero spans recorded.
    let off = DdmEngine::builder().threads(3).pool(Arc::clone(&pool)).build();
    let mut quiet = off.session(1);
    assert!(!quiet.trace_enabled());
    quiet.load_dense_1d(&subs, &upds);
    quiet.commit();
    assert!(quiet.drain_trace().is_empty(), "untraced session recorded spans");
    assert_eq!(quiet.trace_dropped(), 0);
}

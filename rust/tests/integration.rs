//! Cross-module integration tests: algorithms × workloads × service ×
//! XLA backend. (Unit tests live in each module; these exercise the
//! composed system.)

use ddm::algos::{Algo, MatchParams};
use ddm::core::sink::{canonicalize, VecSink};
use ddm::core::{ddim, RegionsNd};
use ddm::exec::ThreadPool;
use ddm::hla::{RegionKind, RegionSpec, RoutingSpace};
use ddm::prng::Rng;
use ddm::sets::SetImpl;
use ddm::workload::koln::{koln_workload, KolnParams};
use ddm::workload::{alpha_workload, clustered_workload, AlphaParams};

/// Every algorithm × every workload family × several thread counts
/// produce the identical pair set.
#[test]
fn all_algorithms_agree_across_workloads() {
    let pool = ThreadPool::new(7);
    let params = MatchParams {
        ncells: 128,
        set_impl: SetImpl::Bit,
    };
    let ap = AlphaParams {
        n_total: 3_000,
        alpha: 10.0,
        space: 1e5,
    };
    let workloads: Vec<(&str, _)> = vec![
        ("uniform", alpha_workload(31, &ap)),
        ("clustered", clustered_workload(32, &ap, 4, 800.0)),
        (
            "koln",
            koln_workload(33, &KolnParams::default().scaled(0.003)),
        ),
    ];
    for (name, (subs, upds)) in workloads {
        let reference = ddm::algos::run_pairs(Algo::Bfm, &pool, 1, &subs, &upds, &params);
        for algo in Algo::ALL {
            for p in [1, 3, 8] {
                let got = ddm::algos::run_pairs(algo, &pool, p, &subs, &upds, &params);
                assert_eq!(
                    got,
                    reference,
                    "{name}/{}/P={p} disagrees with BFM",
                    algo.name()
                );
            }
        }
    }
}

/// The d-dimensional reduction with each parallel 1-D matcher equals
/// the direct d-rectangle check.
#[test]
fn ddim_reduction_with_every_algo() {
    let pool = ThreadPool::new(3);
    let params = MatchParams {
        ncells: 32,
        set_impl: SetImpl::BTree,
    };
    let mut rng = Rng::new(0x1717);
    for d in [2usize, 3] {
        let mut subs = RegionsNd::new(d);
        let mut upds = RegionsNd::new(d);
        for _ in 0..150 {
            let rect: Vec<ddm::core::Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 100.0);
                    ddm::core::Interval::new(lo, lo + rng.uniform(0.0, 15.0))
                })
                .collect();
            subs.push(&rect);
        }
        for _ in 0..120 {
            let rect: Vec<ddm::core::Interval> = (0..d)
                .map(|_| {
                    let lo = rng.uniform(0.0, 100.0);
                    ddm::core::Interval::new(lo, lo + rng.uniform(0.0, 15.0))
                })
                .collect();
            upds.push(&rect);
        }
        let mut want = Vec::new();
        for i in 0..subs.len() {
            for j in 0..upds.len() {
                if subs.rects_intersect(i, &upds, j) {
                    want.push((i as u32, j as u32));
                }
            }
        }
        for algo in [Algo::Psbm, Algo::Itm, Algo::Gbm] {
            let mut sink = VecSink::default();
            ddim::match_nd(
                &subs,
                &upds,
                |s1, u1, out| {
                    out.pairs
                        .extend(ddm::algos::run_pairs(algo, &pool, 4, s1, u1, &params));
                },
                &mut sink,
            );
            assert_eq!(
                canonicalize(sink.pairs),
                want,
                "d={d} algo={}",
                algo.name()
            );
        }
    }
}

/// Service end-to-end: Fig. 1 style scenario — registrations, full
/// match, publish/poll routing, dynamic moves — all consistent.
#[test]
fn service_scenario_consistency() {
    let mut svc = ddm::hla::DdmService::new(RoutingSpace::uniform(1, 100_000));
    let fed_a = svc.join("a");
    let fed_b = svc.join("b");
    let mut rng = Rng::new(0x5E5E);
    let mut subs = Vec::new();
    for _ in 0..200 {
        let x = rng.below(99_000);
        subs.push(
            svc.register(
                fed_a,
                RegionKind::Subscription,
                &RegionSpec::interval(x, x + 500),
            )
            .unwrap(),
        );
    }
    let mut upds = Vec::new();
    for _ in 0..100 {
        let x = rng.below(99_000);
        upds.push(
            svc.register(fed_b, RegionKind::Update, &RegionSpec::interval(x, x + 300))
                .unwrap(),
        );
    }
    let pool = ThreadPool::new(3);
    let pairs = svc.match_all(Algo::Psbm, &pool, 4, &MatchParams::default());

    // Publishing every update must deliver exactly the matched pairs.
    let mut delivered = 0;
    for &u in &upds {
        delivered += svc.publish(u, 1).unwrap();
    }
    assert_eq!(delivered, pairs.len());
    assert_eq!(svc.poll(fed_a).len(), delivered);

    // Dynamic: move every subscription; match count changes coherently.
    for &s in subs.iter().take(50) {
        let x = rng.below(99_000);
        svc.modify(s, &RegionSpec::interval(x, x + 500)).unwrap();
    }
    let pairs2 = svc.match_all(Algo::Itm, &pool, 4, &MatchParams::default());
    let pairs3 = svc.match_all(Algo::Gbm, &pool, 2, &MatchParams::default());
    let norm = |mut v: Vec<(ddm::hla::RegionHandle, ddm::hla::RegionHandle)>| {
        v.sort_by_key(|(a, b)| (a.id, b.id));
        v
    };
    assert_eq!(norm(pairs2), norm(pairs3));
}

/// XLA backend agrees with native matching on service-shaped data
/// (skips when `make artifacts` has not run).
#[test]
fn xla_backend_matches_native_on_service_regions() {
    let dir = std::path::Path::new(ddm::runtime::DEFAULT_ARTIFACT_DIR);
    if !ddm::runtime::artifacts_available(dir) {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let be = ddm::runtime::XlaMatchBackend::load(dir).expect("backend");
    let pool = ThreadPool::new(3);
    let params = MatchParams::default();
    let mut rng = Rng::new(0xCAFE);
    // Integer (HLA-style) coordinates are f32-exact below 2^24.
    let mut subs = ddm::core::Regions1D::default();
    let mut upds = ddm::core::Regions1D::default();
    for _ in 0..500 {
        let x = rng.below(1_000_000) as f64;
        subs.push(ddm::core::Interval::new(x, x + 1000.0));
    }
    for _ in 0..700 {
        let x = rng.below(1_000_000) as f64;
        upds.push(ddm::core::Interval::new(x, x + 800.0));
    }
    let k_native = ddm::algos::run_count(Algo::Psbm, &pool, 4, &subs, &upds, &params);
    let k_xla = be.match_counts_1d(&subs, &upds).expect("xla count");
    assert_eq!(k_native, k_xla);

    let pairs_native =
        ddm::algos::run_pairs(Algo::Bfm, &pool, 1, &subs, &upds, &params);
    let mut pairs_xla = be.match_pairs_1d(&subs, &upds).expect("xla pairs");
    pairs_xla.sort_unstable();
    assert_eq!(pairs_native, pairs_xla);
}

/// Coordinator smoke: concurrent clients against one service loop.
#[test]
fn coordinator_handles_concurrent_clients() {
    use ddm::coordinator::{Coordinator, CoordinatorConfig};
    let coord = Coordinator::spawn(CoordinatorConfig {
        space: RoutingSpace::uniform(1, 1_000_000),
        nthreads: 2,
        ..Default::default()
    });
    let c = coord.client();
    let fed = c.join("shared");
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let c = coord.client();
            s.spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..50 {
                    let x = rng.below(990_000);
                    let h = c
                        .register(
                            fed,
                            RegionKind::Subscription,
                            RegionSpec::interval(x, x + 100),
                        )
                        .unwrap();
                    c.modify(h, RegionSpec::interval(x, x + 200)).unwrap();
                }
            });
        }
    });
    let m = c.metrics();
    assert_eq!(m.counter("registers"), 200);
    assert_eq!(m.counter("modifies"), 200);
    let metrics = coord.shutdown();
    assert_eq!(metrics.counter("registers"), 200);
}

/// Thread-count invariance under the property harness (heavier than
/// the per-module variants: full workload, many P values).
#[test]
fn psbm_thread_invariance_heavy() {
    let pool = ThreadPool::new(15);
    let ap = AlphaParams {
        n_total: 10_000,
        alpha: 100.0,
        space: 1e6,
    };
    let (subs, upds) = alpha_workload(77, &ap);
    let params = MatchParams::default();
    let want = ddm::algos::run_pairs(Algo::Psbm, &pool, 1, &subs, &upds, &params);
    for p in 2..=16 {
        let got = ddm::algos::run_pairs(Algo::Psbm, &pool, p, &subs, &upds, &params);
        assert_eq!(got.len(), want.len(), "P={p}");
        assert_eq!(got, want, "P={p}");
    }
}

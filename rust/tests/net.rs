//! End-to-end tests of the network service over real loopback sockets.
//!
//! Everything here runs against `127.0.0.1:0` (ephemeral ports) with
//! short client read timeouts, so a protocol bug fails fast instead of
//! hanging the suite. The two headline properties:
//!
//! * **wire = local**: a session driven over TCP produces diff streams
//!   byte-equal (epoch numbers included) to the same ops applied to an
//!   in-process session;
//! * **federation = flat**: a router + two stripe-owning workers
//!   produce diff streams and pair sets byte-equal to one flat
//!   `ShardedSession` over the same global cuts.

use std::io::{Read, Write};
use std::time::Duration;

use ddm::bench::netbench::bench_loopback;
use ddm::core::Interval;
use ddm::engine::DdmEngine;
use ddm::net::proto::arbitrary_msg;
use ddm::net::{
    assign_stripes, serve, FederationClient, Msg, NetClient, RegionOp, RouterService,
    ServerConfig, ServerHandle, TopologySnapshot, WireError, WorkerService,
};
use ddm::prng::Rng;
use ddm::shard::{AnySession, SpacePartitioner};

const D: usize = 2;
const SPACE: f64 = 1e6;

fn cfg() -> ServerConfig {
    ServerConfig {
        listen: "127.0.0.1:0".to_string(),
        io_threads: 2,
    }
}

fn single_server() -> (ServerHandle, String) {
    let engine = DdmEngine::builder().threads(2).build();
    let handle = serve(&cfg(), WorkerService::new(AnySession::Single(engine.session(D))))
        .expect("serve single worker");
    let addr = handle.addr().to_string();
    (handle, addr)
}

fn connect(addr: &str) -> NetClient {
    let mut c = NetClient::connect(addr).expect("connect");
    c.set_timeout(Duration::from_secs(10)).expect("timeout");
    c
}

fn rect(lo0: f64, hi0: f64, lo1: f64, hi1: f64) -> Vec<Interval> {
    vec![Interval::new(lo0, hi0), Interval::new(lo1, hi1)]
}

// ---- single server ----------------------------------------------------

/// One connection, three epochs: the wire-observed diff stream equals
/// an in-process replay (asserted inside `bench_loopback`), and the
/// server's own metrics agree on the commit count.
#[test]
fn loopback_single_connection_matches_local_replay() {
    let (handle, addr) = single_server();
    let res = bench_loopback(&addr, 1, 400, 3, 7, D).expect("loopback equivalence");
    assert!(res.ops > 0 && res.added > 0);
    let metrics = handle.shutdown();
    assert_eq!(metrics.counter("commits"), 3);
    assert_eq!(metrics.counter("net_ops"), res.ops as u64);
}

/// Three connections staging disjoint key ranges concurrently still
/// replay to the identical diff stream.
#[test]
fn loopback_multi_connection_matches_local_replay() {
    let (handle, addr) = single_server();
    let res = bench_loopback(&addr, 3, 300, 3, 11, D).expect("loopback equivalence");
    assert!(res.added > 0);
    let metrics = handle.shutdown();
    assert_eq!(metrics.counter("net_conns"), 3);
}

/// The committing connection receives the epoch's diff exactly once
/// even while subscribed: the broadcast skips it, the direct reply
/// carries it. A subscribed bystander gets the identical frame.
#[test]
fn commit_reply_is_not_duplicated_to_subscribed_committer() {
    let (handle, addr) = single_server();
    let mut a = connect(&addr);
    let mut b = connect(&addr);
    a.subscribe().expect("subscribe a");
    b.subscribe().expect("subscribe b");
    // Barrier so the server has registered both subscriptions before
    // the commit below broadcasts.
    a.sync(1).expect("sync a");
    b.sync(2).expect("sync b");

    a.op(RegionOp::UpsertSub { key: 0, rect: rect(0.0, 10.0, 0.0, 10.0) })
        .expect("stage sub");
    a.op(RegionOp::UpsertUpd { key: 7, rect: rect(5.0, 15.0, 5.0, 15.0) })
        .expect("stage upd");
    let diff_a = a.commit().expect("commit");
    assert_eq!(diff_a.epoch, 1);
    assert_eq!(diff_a.added, vec![(0, 7)]);
    let diff_b = b.await_diff().expect("broadcast diff");
    assert_eq!(diff_a, diff_b);

    // Any duplicate diff would have been queued to `a` before this
    // SyncAck; after it, `a`'s socket must be silent.
    a.sync(3).expect("post-commit sync");
    a.set_timeout(Duration::from_millis(200)).expect("short timeout");
    assert!(a.recv().is_err(), "committer received a duplicate frame");
    drop((a, b));
    handle.shutdown();
}

/// `GetMetrics` round-trips the live counters: ops staged, epochs
/// committed, connections seen, diff frames sent.
#[test]
fn metrics_travel_over_the_wire() {
    let (handle, addr) = single_server();
    let mut c = connect(&addr);
    c.op(RegionOp::UpsertSub { key: 1, rect: rect(0.0, 5.0, 0.0, 5.0) })
        .expect("stage");
    c.op(RegionOp::UpsertUpd { key: 2, rect: rect(1.0, 6.0, 1.0, 6.0) })
        .expect("stage");
    let diff = c.commit().expect("commit");
    assert_eq!(diff.added, vec![(1, 2)]);
    let snap = c.metrics().expect("metrics frame");
    assert_eq!(snap.counter("commits"), 1);
    assert_eq!(snap.counter("net_ops"), 2);
    assert_eq!(snap.counter("net_diff_frames"), 1);
    assert!(snap.counter("net_conns") >= 1);
    assert!(!snap.table().render().is_empty());
    drop(c);
    handle.shutdown();
}

/// A corrupt frame gets a typed `ErrorReply` and a close — the server
/// neither panics nor leaves the connection dangling.
#[test]
fn corrupt_frame_yields_error_reply_then_close() {
    let (handle, addr) = single_server();
    let mut raw = std::net::TcpStream::connect(&addr).expect("raw connect");
    raw.set_read_timeout(Some(Duration::from_secs(10))).expect("timeout");
    // A well-framed body with an unknown version byte.
    raw.write_all(&[2, 0, 0, 0, 99, 1, 0, 0]).expect("write garbage");
    let mut buf = Vec::new();
    let mut tmp = [0u8; 1024];
    let reply = loop {
        match Msg::decode(&buf).expect("decodable reply") {
            Some((msg, _)) => break msg,
            None => {
                let n = raw.read(&mut tmp).expect("read reply");
                assert!(n > 0, "closed before replying");
                buf.extend_from_slice(&tmp[..n]);
            }
        }
    };
    match reply {
        Msg::ErrorReply { code, .. } => assert_eq!(code, ddm::net::proto::err_code::BAD_FRAME),
        other => panic!("expected ErrorReply, got {other:?}"),
    }
    // The server closes the connection after the reply.
    loop {
        match raw.read(&mut tmp) {
            Ok(0) => break,
            Ok(_) => continue,
            Err(e) => panic!("expected EOF after error reply, got {e}"),
        }
    }
    // The server itself is still healthy.
    let mut c = connect(&addr);
    c.sync(9).expect("server still serving");
    drop(c);
    handle.shutdown();
}

// ---- graceful shutdown ------------------------------------------------

/// The shutdown regression: ops staged (and even flushed) but never
/// committed still surface. `Shutdown` closes a final epoch, streams
/// the diff to subscribers, and says `Goodbye` before the socket dies.
#[test]
fn graceful_shutdown_flushes_staged_ops_and_says_goodbye() {
    let (handle, addr) = single_server();
    let mut c = connect(&addr);
    c.subscribe().expect("subscribe");
    c.op(RegionOp::UpsertSub { key: 3, rect: rect(0.0, 9.0, 0.0, 9.0) })
        .expect("stage sub");
    c.op(RegionOp::UpsertUpd { key: 4, rect: rect(2.0, 11.0, 2.0, 11.0) })
        .expect("stage upd");
    // Flush applies the batch without closing an epoch — the classic
    // way to lose work at shutdown if only pending_ops() is checked.
    c.flush().expect("flush");
    c.sync(1).expect("barrier");
    c.shutdown_server().expect("request shutdown");
    let diff = c.await_diff().expect("final diff before goodbye");
    assert_eq!(diff.epoch, 1);
    assert_eq!(diff.added, vec![(3, 4)]);
    let epoch = c.await_goodbye().expect("goodbye");
    assert_eq!(epoch, 1);
    let metrics = handle.join();
    assert_eq!(metrics.counter("commits"), 1, "shutdown must close the final epoch");
}

// ---- snapshot reads & admission control -------------------------------

/// `GetPairs` answers from the published `EpochSnapshot`, so a wire
/// read is byte-equal to an in-process read at every observable point:
/// empty before the first commit, unchanged while ops sit staged or
/// queued, and exactly the committed pair set after each epoch.
#[test]
fn get_pairs_is_served_from_the_published_snapshot() {
    let (handle, addr) = single_server();
    let mut c = connect(&addr);

    // In-process twin running the identical script.
    let engine = DdmEngine::builder().threads(2).build();
    let mut local = engine.session(D);

    assert_eq!(c.pairs().expect("pairs@0"), local.pairs(), "pre-commit");

    c.op(RegionOp::UpsertSub { key: 1, rect: rect(0.0, 10.0, 0.0, 10.0) })
        .expect("stage sub");
    c.op(RegionOp::UpsertUpd { key: 2, rect: rect(5.0, 15.0, 5.0, 15.0) })
        .expect("stage upd");
    local.upsert_subscription(1, &rect(0.0, 10.0, 0.0, 10.0));
    local.upsert_update(2, &rect(5.0, 15.0, 5.0, 15.0));
    c.sync(1).expect("barrier");
    // Queued-but-uncommitted ops are invisible to readers on both
    // sides: the published snapshot still says epoch 0.
    assert_eq!(c.pairs().expect("pairs staged"), local.pairs(), "staged ops leaked");
    assert!(c.pairs().expect("pairs staged").is_empty());

    let diff = c.commit().expect("commit");
    let local_diff = local.commit();
    assert_eq!(diff, local_diff, "wire diff != local diff");
    assert_eq!(c.pairs().expect("pairs@1"), local.pairs(), "post-commit");
    assert_eq!(c.pairs().expect("pairs@1"), vec![(1, 2)]);
    drop(c);
    handle.shutdown();
}

/// Admission control with a tiny backlog: the op over the bound gets a
/// typed `Busy { pending, limit }` reply instead of unbounded
/// buffering, the rejected op never reaches the session, and after a
/// commit drains the queue the same op is admitted again.
#[test]
fn full_backlog_yields_typed_busy_reply() {
    let engine = DdmEngine::builder().threads(2).build();
    let svc = WorkerService::with_backlog(AnySession::Single(engine.session(D)), 2);
    let handle = serve(&cfg(), svc).expect("serve tiny-backlog worker");
    let mut c = connect(&handle.addr().to_string());

    c.op(RegionOp::UpsertSub { key: 1, rect: rect(0.0, 10.0, 0.0, 10.0) })
        .expect("stage 1/2");
    c.op(RegionOp::UpsertUpd { key: 2, rect: rect(5.0, 15.0, 5.0, 15.0) })
        .expect("stage 2/2");
    let (_, pending) = c.sync(1).expect("barrier");
    assert_eq!(pending, 2, "both ops queued in the backlog");

    // Third op overflows the bound: the reply is Busy, not silence.
    c.send(&Msg::Op(RegionOp::UpsertUpd { key: 9, rect: rect(0.0, 8.0, 0.0, 8.0) }))
        .expect("send over-limit op");
    match c.recv().expect("busy reply") {
        Msg::Busy { pending, limit } => {
            assert_eq!((pending, limit), (2, 2));
        }
        other => panic!("expected Busy, got {other:?}"),
    }

    // The rejected op left no trace: the epoch closes with only the
    // two admitted ops.
    let diff = c.commit().expect("commit");
    assert_eq!(diff.added, vec![(1, 2)], "rejected op leaked into the epoch");

    // The commit drained the queue — the same op is admitted now.
    c.op(RegionOp::UpsertUpd { key: 9, rect: rect(0.0, 8.0, 0.0, 8.0) })
        .expect("retry after drain");
    let (_, pending) = c.sync(2).expect("barrier");
    assert_eq!(pending, 1, "retried op queued");
    let diff = c.commit().expect("second commit");
    assert_eq!(diff.added, vec![(1, 9)]);

    let snap = c.metrics().expect("metrics");
    assert_eq!(snap.counter("net_busy"), 1);
    assert_eq!(snap.counter("net_ops"), 3);
    drop(c);
    handle.shutdown();
}

// ---- federation -------------------------------------------------------

/// Build a router + `n_workers` workers over `shards` uniform stripes
/// and return the handles plus the flat reference partitioner cuts.
fn federation(
    shards: usize,
    n_workers: usize,
) -> (Vec<ServerHandle>, ServerHandle, Vec<f64>) {
    let part = SpacePartitioner::uniform(shards, 0, Interval::new(0.0, SPACE));
    let cuts = part.cuts().to_vec();
    let mut entries = assign_stripes(shards, &vec![String::new(); n_workers]);
    let mut handles = Vec::new();
    for e in &mut entries {
        let local =
            SpacePartitioner::from_cuts(0, cuts[e.first as usize..e.last as usize].to_vec());
        let engine = DdmEngine::builder().threads(2).build();
        let sess = AnySession::Sharded(engine.sharded_session_with(D, local));
        let h = serve(&cfg(), WorkerService::new(sess)).expect("serve worker");
        e.addr = h.addr().to_string();
        handles.push(h);
    }
    let topo = TopologySnapshot {
        d: D as u32,
        split_dim: 0,
        cuts: cuts.clone(),
        workers: entries,
    };
    let router = serve(&cfg(), RouterService::new(topo)).expect("serve router");
    (handles, router, cuts)
}

/// Random churn script over the full space: upserts (many straddling
/// stripe and worker boundaries), moves, and removes.
fn churn(seed: u64, n: usize, epochs: usize) -> Vec<Vec<RegionOp>> {
    let mut rng = Rng::new(seed);
    let mut r = |rng: &mut Rng, wide: bool| -> Vec<Interval> {
        let w = if wide { SPACE * 0.6 } else { SPACE * 0.01 };
        (0..D)
            .map(|_| {
                let lo = rng.uniform(0.0, SPACE - w);
                Interval::new(lo, lo + rng.uniform(w * 0.5, w))
            })
            .collect()
    };
    let mut script = Vec::new();
    let mut first = Vec::new();
    for k in 0..n as u32 {
        let wide = k % 7 == 0;
        first.push(RegionOp::UpsertSub { key: k, rect: r(&mut rng, wide) });
        first.push(RegionOp::UpsertUpd { key: k, rect: r(&mut rng, !wide && k % 5 == 0) });
    }
    script.push(first);
    for _ in 1..epochs {
        let mut ops = Vec::new();
        for _ in 0..(n / 3).max(1) {
            let key = rng.below(n as u64) as u32;
            ops.push(match rng.below(6) {
                0 => RegionOp::RemoveSub { key },
                1 => RegionOp::RemoveUpd { key },
                2 => RegionOp::UpsertSub { key, rect: r(&mut rng, true) },
                3 => RegionOp::UpsertUpd { key, rect: r(&mut rng, true) },
                4 => RegionOp::UpsertSub { key, rect: r(&mut rng, false) },
                _ => RegionOp::UpsertUpd { key, rect: r(&mut rng, false) },
            });
        }
        script.push(ops);
    }
    script
}

fn apply_flat(sess: &mut AnySession, ops: &[RegionOp]) {
    for op in ops {
        match op {
            RegionOp::UpsertSub { key, rect } => sess.upsert_subscription(*key, rect),
            RegionOp::UpsertUpd { key, rect } => sess.upsert_update(*key, rect),
            RegionOp::RemoveSub { key } => sess.remove_subscription(*key),
            RegionOp::RemoveUpd { key } => sess.remove_update(*key),
        }
    }
}

fn apply_fed(fed: &mut FederationClient, ops: &[RegionOp]) {
    for op in ops {
        match op {
            RegionOp::UpsertSub { key, rect } => fed.upsert_subscription(*key, rect),
            RegionOp::UpsertUpd { key, rect } => fed.upsert_update(*key, rect),
            RegionOp::RemoveSub { key } => fed.remove_subscription(*key),
            RegionOp::RemoveUpd { key } => fed.remove_update(*key),
        }
        .expect("federated op");
    }
}

/// The tentpole equivalence: router + 2 workers (each a 2-stripe
/// sharded session) vs one flat 4-stripe `ShardedSession`. Every
/// epoch's merged diff and the final pair set must be byte-equal, so
/// pairs straddling the worker boundary report exactly once.
#[test]
fn federation_matches_flat_sharded_session() {
    let (workers, router, cuts) = federation(4, 2);
    let mut fed = FederationClient::connect(&router.addr().to_string()).expect("fed connect");
    assert_eq!(fed.n_workers(), 2);
    assert_eq!(fed.d(), D);
    fed.set_timeout(Duration::from_secs(10)).expect("timeouts");

    let engine = DdmEngine::builder().threads(2).build();
    let mut flat =
        AnySession::Sharded(engine.sharded_session_with(D, SpacePartitioner::from_cuts(0, cuts)));

    for (e, ops) in churn(1234, 120, 5).iter().enumerate() {
        apply_fed(&mut fed, ops);
        let got = fed.commit().expect("federated commit");
        apply_flat(&mut flat, ops);
        let want = flat.commit();
        assert_eq!(got, want, "epoch {e}: federated diff != flat sharded diff");
        assert_eq!(fed.epoch(), want.epoch);
    }
    assert_eq!(fed.pairs().expect("federated pairs"), flat.pairs());
    assert_eq!(fed.n_pairs(), flat.n_pairs());

    fed.shutdown_workers().expect("worker shutdown");
    for h in workers {
        h.join();
    }
    router.shutdown();
}

/// The router answers topology queries and survives clients that only
/// ever talk to it; a `FederationClient` built from its snapshot and
/// one built by hand are interchangeable.
#[test]
fn router_serves_topology() {
    let (workers, router, cuts) = federation(3, 3);
    let mut c = connect(&router.addr().to_string());
    assert_eq!(c.role(), ddm::net::Role::Router);
    let topo = c.topology().expect("topology frame");
    assert_eq!(topo.d, D as u32);
    assert_eq!(topo.cuts, cuts);
    assert_eq!(topo.workers.len(), 3);
    assert_eq!(topo.shards(), 3);
    // One stripe each, in order.
    for (w, e) in topo.workers.iter().enumerate() {
        assert_eq!((e.first, e.last), (w as u32, w as u32));
    }
    let mut fed = FederationClient::from_topology(&topo).expect("fed from snapshot");
    fed.upsert_subscription(0, &rect(0.0, SPACE * 0.9, 0.0, 10.0)).expect("wide sub");
    fed.upsert_update(1, &rect(0.0, SPACE * 0.9, 0.0, 10.0)).expect("wide upd");
    let diff = fed.commit().expect("commit");
    assert_eq!(diff.added, vec![(0, 1)], "straddling pair reported exactly once");
    fed.shutdown_workers().expect("worker shutdown");
    for h in workers {
        h.join();
    }
    router.shutdown();
}

// ---- timeouts & retry/backoff -----------------------------------------

/// A listener that accepts and then never speaks: with a deadline the
/// handshake fails in bounded time instead of hanging the client
/// forever (the regression `--timeout-ms` exists to prevent).
#[test]
fn silent_listener_times_out_instead_of_hanging() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind silent listener");
    let addr = listener.local_addr().expect("addr").to_string();
    // Keep accepted sockets alive (but mute) so the client sees an
    // open connection, not a reset.
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let held = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    let (stop2, held2) = (std::sync::Arc::clone(&stop), std::sync::Arc::clone(&held));
    let accepter = std::thread::spawn(move || {
        listener
            .set_nonblocking(true)
            .expect("nonblocking accept loop");
        while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
            match listener.accept() {
                Ok((sock, _)) => held2.lock().unwrap().push(sock),
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
        }
    });

    let t0 = std::time::Instant::now();
    let res = NetClient::connect_with(&addr, Duration::from_millis(300));
    let elapsed = t0.elapsed();
    assert!(res.is_err(), "handshake against a silent listener must fail");
    assert!(
        elapsed < Duration::from_secs(5),
        "timeout did not bound the handshake: {elapsed:?}"
    );

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    accepter.join().expect("accepter thread");
}

/// A burst far over the worker's admission cap still lands completely:
/// `Busy` rejections surface as typed retryable errors, the settle
/// loop backs off, flushes, and resends in `limit`-sized chunks until
/// the barrier reports a clean backlog — and the epoch that follows
/// contains every op exactly once.
#[test]
fn over_cap_burst_eventually_lands_every_op() {
    const CAP: usize = 4;
    const KEYS: u32 = 30;
    let engine = DdmEngine::builder().threads(2).build();
    let svc = WorkerService::with_backlog(AnySession::Single(engine.session(D)), CAP);
    let handle = serve(&cfg(), svc).expect("serve tiny-backlog worker");

    let topo = TopologySnapshot {
        d: D as u32,
        split_dim: 0,
        cuts: Vec::new(),
        workers: vec![ddm::net::WorkerEntry {
            addr: handle.addr().to_string(),
            first: 0,
            last: 0,
        }],
    };
    let mut fed = FederationClient::from_topology(&topo).expect("fed connect");

    // 2×KEYS ops in one burst, 15× the backlog cap: every key's sub
    // and upd share a rect, so the epoch must end with KEYS pairs.
    for k in 0..KEYS {
        let lo = f64::from(k) * 10.0;
        let r = rect(lo, lo + 5.0, 0.0, 5.0);
        fed.upsert_subscription(k, &r).expect("burst sub");
        fed.upsert_update(k, &r).expect("burst upd");
    }
    let diff = fed.commit().expect("settle + commit over-cap burst");
    assert_eq!(
        diff.added.len(),
        KEYS as usize,
        "retry/backoff dropped ops: {} of {KEYS} pairs arrived",
        diff.added.len()
    );
    for k in 0..KEYS {
        assert!(diff.added.contains(&(k, k)), "pair ({k},{k}) missing");
    }
    assert_eq!(fed.n_pairs(), KEYS as usize);

    // The server really did reject ops along the way (the test is
    // meaningless if the burst fit the backlog).
    let snaps = fed.worker_metrics().expect("metrics");
    assert!(
        snaps[0].counter("net_busy") > 0,
        "burst never overflowed the cap — raise KEYS or lower CAP"
    );

    fed.shutdown_workers().expect("worker shutdown");
    handle.join();
}

// ---- wire fuzz --------------------------------------------------------

/// Every frame type round-trips at several dimensionalities, and no
/// truncation or byte corruption of a valid frame can panic the
/// decoder — it returns `Ok(None)` (incomplete) or a typed error.
#[test]
fn wire_fuzz_roundtrip_and_corruption() {
    let mut rng = Rng::new(0xAB5E);
    for d in [1usize, 3, 5] {
        for _ in 0..200 {
            let msg = arbitrary_msg(&mut rng, d);
            let frame = msg.to_frame();
            assert_eq!(Msg::decode_exact(&frame).expect("round trip"), msg);
            // Every strict prefix is "incomplete", never an error.
            for cut in 0..frame.len() {
                match Msg::decode(&frame[..cut]) {
                    Ok(None) | Err(_) => {}
                    Ok(Some(_)) => panic!("prefix of length {cut} decoded as complete"),
                }
            }
            // Single-byte corruption never panics.
            for _ in 0..8 {
                let mut bad = frame.clone();
                let at = rng.below(bad.len() as u64) as usize;
                bad[at] ^= 1 << rng.below(8);
                let _ = Msg::decode(&bad);
            }
        }
    }
    // Oversized length prefixes are rejected up front.
    let huge = [0xFF, 0xFF, 0xFF, 0x7F, 1, 1];
    assert!(matches!(Msg::decode(&huge), Err(WireError::Oversized(_))));
}

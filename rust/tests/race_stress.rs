//! Randomized stress tests for the claim-checked parallel seams.
//!
//! Every lock-free fan-in/scatter path in the crate writes through
//! `exec::claims` (`DisjointWriter` / `ClaimedSlice` / `FanSlots` /
//! `TakeCells`). This suite drives those seams across thread counts
//! P ∈ {1, 2, 4, 8} and adversarial sizes (empty, one element, the
//! insertion-sort cutoff 64 ± 1, primes, the parallel cutoff 8192 ± 1)
//! and asserts the parallel results are bit-identical to a serial
//! oracle.
//!
//! Run it twice:
//!
//! ```text
//! cargo test --test race_stress                        # release contracts
//! cargo test --test race_stress --features race-check  # claim-word teeth
//! ```
//!
//! Under `race-check` every claim transition is tracked in per-index
//! atomic words, so an overlapping write anywhere in these paths
//! panics deterministically instead of silently racing (see the
//! `claim_teeth` module at the bottom).

use ddm::algos::gbm::{self, CellList, Dedup, GbmParams};
use ddm::core::{sink, Interval, Regions1D, VecSink};
use ddm::exec::radix::{par_radix_sort_by_key, radix_sort_by_key, RadixScratch};
use ddm::exec::{psort, scan, ThreadPool};
use ddm::prng::Rng;

/// Sizes chosen to straddle every cutoff in the exec layer: the
/// radix/psort insertion cutoff (64) and the radix parallel cutoff
/// (8192), plus empty, singleton, and prime sizes that never divide
/// evenly across workers.
const SIZES: &[usize] = &[0, 1, 2, 63, 64, 65, 97, 1009, 8191, 8192, 8193];
const THREADS: &[usize] = &[1, 2, 4, 8];

fn pool() -> ThreadPool {
    ThreadPool::new(8)
}

fn mix(i: usize) -> u64 {
    (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17)
}

#[test]
fn fan_map_matches_serial_map() {
    let pool = pool();
    for &p in THREADS {
        for &n in SIZES {
            let got: Vec<u64> = pool.fan_map(p, n, mix);
            let want: Vec<u64> = (0..n).map(mix).collect();
            assert_eq!(got, want, "fan_map p={p} n={n}");
        }
    }
}

#[test]
fn fan_map_take_moves_every_item_exactly_once() {
    let pool = pool();
    for &p in THREADS {
        for &n in SIZES {
            // Boxed (non-Clone, non-Default) items: ownership must
            // transfer through the TakeCells seam exactly once.
            let items: Vec<Box<u64>> = (0..n).map(|i| Box::new(mix(i))).collect();
            let got: Vec<u64> = pool.fan_map_take(p, items, |_p, b| *b ^ 1);
            let want: Vec<u64> = (0..n).map(|i| mix(i) ^ 1).collect();
            assert_eq!(got, want, "fan_map_take p={p} n={n}");
        }
    }
}

#[test]
fn radix_sort_is_bit_identical_to_stable_oracle() {
    let pool = pool();
    for &p in THREADS {
        for &n in SIZES {
            let mut rng = Rng::new(0x0AD5 ^ mix(n) ^ ((p as u64) << 56));
            // Narrow key range forces ties, making stability observable
            // through the payload (= input position).
            let base: Vec<(u64, u32)> = (0..n)
                .map(|i| (rng.next_u64() % 61, i as u32))
                .collect();
            let mut want = base.clone();
            want.sort_by_key(|&(k, _)| k);
            let mut got = base;
            let mut aux = Vec::new();
            let mut scratch = RadixScratch::new();
            par_radix_sort_by_key(&pool, p, &mut got, &mut aux, &mut scratch, |&(k, _)| k);
            assert_eq!(got, want, "radix p={p} n={n}");
        }
    }
}

#[test]
fn radix_parallel_agrees_with_radix_serial() {
    let pool = pool();
    for &n in SIZES {
        let mut rng = Rng::new(mix(n + 11));
        let base: Vec<(u64, u32)> = (0..n)
            .map(|i| (rng.next_u64(), i as u32))
            .collect();
        let mut serial = base.clone();
        let (mut aux, mut scratch) = (Vec::new(), RadixScratch::new());
        radix_sort_by_key(&mut serial, &mut aux, &mut scratch, |&(k, _)| k);
        for &p in THREADS {
            let mut par = base.clone();
            let (mut aux, mut scratch) = (Vec::new(), RadixScratch::new());
            par_radix_sort_by_key(&pool, p, &mut par, &mut aux, &mut scratch, |&(k, _)| k);
            assert_eq!(par, serial, "radix serial-vs-parallel p={p} n={n}");
        }
    }
}

#[test]
fn psort_is_bit_identical_to_std_oracle() {
    let pool = pool();
    for &p in THREADS {
        for &n in SIZES {
            let mut rng = Rng::new(mix(n) ^ (p as u64));
            // Composite key (key, position) is a total order, so the
            // sorted array is unique and any sub-merge claim bug shows
            // up as a literal mismatch.
            let base: Vec<(u64, u32)> = (0..n)
                .map(|i| (rng.next_u64() % 127, i as u32))
                .collect();
            let mut want = base.clone();
            want.sort_unstable_by_key(|&(k, id)| (k, id));
            let mut got = base;
            psort::par_sort_by_key(&pool, p, &mut got, |&(k, id)| (k, id));
            assert_eq!(got, want, "psort p={p} n={n}");
        }
    }
}

#[test]
fn parallel_scan_matches_serial_scan() {
    let pool = pool();
    for &p in THREADS {
        for &n in SIZES {
            let base: Vec<u64> = (0..n).map(|i| mix(i) % 1000).collect();
            let mut want = base.clone();
            let mut acc = 0u64;
            for x in want.iter_mut() {
                acc += *x;
                *x = acc;
            }
            let mut got = base;
            scan::par_inclusive_scan(&pool, p, &mut got, 0u64, |a, b| a + b);
            assert_eq!(got, want, "scan p={p} n={n}");
        }
    }
}

fn random_regions(rng: &mut Rng, n: usize, span: f64) -> Regions1D {
    let mut r = Regions1D::with_capacity(n);
    for _ in 0..n {
        let lo = rng.uniform(0.0, span);
        let len = rng.uniform(0.0, span / 16.0);
        r.push(Interval::new(lo, lo + len));
    }
    r
}

#[test]
fn gbm_scatter_matches_serial_gbm() {
    let pool = pool();
    // Region counts chosen like SIZES but capped: GBM is quadratic-ish
    // in pathological overlap, and the serial oracle runs every config.
    for &n in &[0usize, 1, 2, 63, 97, 1009, 4001] {
        let mut rng = Rng::new(mix(n + 23));
        let subs = random_regions(&mut rng, n, 1000.0);
        let upds = random_regions(&mut rng, n, 1000.0);
        for cell_list in [CellList::FanIn, CellList::LockFree] {
            let params = GbmParams {
                ncells: 257,
                cell_list,
                dedup: Dedup::FirstCell,
            };
            let mut serial = VecSink::default();
            gbm::match_seq(&subs, &upds, &params, &mut serial);
            let mut want = serial.pairs;
            want.sort_unstable();
            for &p in THREADS {
                let sinks: Vec<VecSink> = gbm::match_par(&pool, p, &subs, &upds, &params);
                let got = sink::canonical_pairs(sinks);
                assert_eq!(got, want, "gbm {cell_list:?} p={p} n={n}");
            }
        }
    }
}

/// MVCC seam under stress: reader threads hammer the published
/// [`EpochSnapshot`](ddm::session::EpochSnapshot) while the writer
/// runs pipelined commits fed from a bounded ingest queue. Readers
/// assert that epochs never go backwards and that every snapshot is
/// internally consistent (pair list, point lookups, and per-side
/// indexes all agree); the writer asserts every published snapshot
/// matches a live read. Under `race-check` the commit's claim-checked
/// parallel phases run with teeth at the same time.
#[test]
fn concurrent_snapshot_readers_survive_pipelined_commits() {
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;

    use ddm::engine::DdmEngine;
    use ddm::session::{ingest_queue, EpochSnapshot, Side};

    const KEYS: u32 = 256;
    const EPOCHS: u64 = 12;
    const READERS: usize = 4;

    let engine = DdmEngine::builder()
        .algo(ddm::algos::Algo::Psbm)
        .threads(2)
        .build();
    let mut sess = engine.session(1);
    let mut rng = Rng::new(0x5EED_CAFE);
    let rect = |rng: &mut Rng| {
        let lo = rng.uniform(0.0, 1000.0);
        [Interval::new(lo, lo + 40.0)]
    };
    for k in 0..KEYS {
        let r = rect(&mut rng);
        sess.upsert_subscription(k, &r);
        let r = rect(&mut rng);
        sess.upsert_update(k, &r);
    }
    let _ = sess.commit();

    let cell = Mutex::new(sess.snapshot());
    let stop = AtomicBool::new(false);
    let (tx, rx) = ingest_queue(1024);

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..READERS)
            .map(|r| {
                let (cell, stop) = (&cell, &stop);
                scope.spawn(move || {
                    let mut last_epoch = 0u64;
                    let mut reads = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        let snap: EpochSnapshot = cell.lock().unwrap().clone();
                        assert!(
                            snap.epoch() >= last_epoch,
                            "reader {r}: epoch went backwards ({} < {last_epoch})",
                            snap.epoch()
                        );
                        last_epoch = snap.epoch();
                        let pairs = snap.pairs();
                        assert_eq!(pairs.len(), snap.n_pairs(), "reader {r}");
                        if let Some(&(s, u)) = pairs.get(reads % pairs.len().max(1)) {
                            assert!(snap.contains_pair(s, u), "reader {r}");
                            assert!(snap.updates_of(s).contains(&u), "reader {r}");
                            assert!(snap.subscriptions_of(u).contains(&s), "reader {r}");
                        }
                        reads += 1;
                    }
                    reads
                })
            })
            .collect();

        // Writer: ops flow through the bounded MPSC front-end, drain
        // into the staged batch, and commit pipelined with the *next*
        // epoch's coalesced batch prewriting the trees.
        for epoch in 0..EPOCHS {
            for i in 0..64u32 {
                let k = (epoch as u32).wrapping_mul(31).wrapping_add(i * 7) % KEYS;
                let side = if i % 2 == 0 { Side::Subscription } else { Side::Update };
                let r = rect(&mut rng);
                tx.try_upsert(side, k, &r).unwrap();
            }
            assert_eq!(sess.drain_ingest(&rx), 64, "epoch {epoch}");
            let (mut next_subs, mut next_upds) = (BTreeMap::new(), BTreeMap::new());
            for i in 0..16u32 {
                let k = (epoch as u32).wrapping_mul(17).wrapping_add(i * 13) % KEYS;
                let r = rect(&mut rng);
                if i % 2 == 0 {
                    next_subs.insert(k, Some(r.to_vec()));
                } else {
                    next_upds.insert(k, Some(r.to_vec()));
                }
            }
            let _ = sess.commit_pipelined(next_subs, next_upds);
            let snap = sess.snapshot();
            assert_eq!(snap.epoch(), sess.epoch(), "epoch {epoch}");
            assert_eq!(snap.pairs(), sess.pairs(), "snapshot != live at epoch {epoch}");
            *cell.lock().unwrap() = snap;
        }
        let _ = sess.commit(); // applies the last prewritten batch
        assert_eq!(sess.snapshot().pairs(), sess.pairs(), "final snapshot != live");
        *cell.lock().unwrap() = sess.snapshot();
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            let _ = h.join().unwrap();
        }
    });
}

/// The teeth themselves: with `race-check` on, an intentionally
/// overlapping write through the claims layer must panic with the
/// worker/site diagnostic instead of silently racing.
#[cfg(feature = "race-check")]
mod claim_teeth {
    use ddm::exec::pool::scoped_region;
    use ddm::exec::DisjointWriter;

    #[test]
    #[should_panic(expected = "overlapping write")]
    fn two_workers_writing_the_same_slot_is_caught() {
        let mut buf = vec![0u64; 4];
        let w = DisjointWriter::new(&mut buf, "stress::overlap");
        let w = &w;
        scoped_region(2, |p| {
            // Both workers write index 0: exactly one CAS wins, the
            // loser panics (and `scoped_region` propagates it).
            // SAFETY: intentionally NOT disjoint — that's the test.
            unsafe { w.write(0, p as u64) };
        });
    }

    #[test]
    #[should_panic(expected = "overlapping claim")]
    fn two_workers_claiming_the_same_range_is_caught() {
        let mut buf = vec![0u64; 8];
        let w = DisjointWriter::new(&mut buf, "stress::overlap-claim");
        let w = &w;
        scoped_region(2, |_p| {
            // SAFETY: intentionally overlapping claims — the second
            // claimant must panic under race-check.
            let mut seg = unsafe { w.claim(2..6) };
            seg.fill(7);
        });
    }
}

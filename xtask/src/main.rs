//! In-tree developer tooling for the ddm workspace (pure `std`).
//!
//! Subcommands:
//!
//! * `cargo run -p xtask -- lint` — source-hygiene lint over `rust/src`:
//!   - **safety-comment**: every `unsafe` block / `unsafe impl` needs an
//!     adjacent `// SAFETY:` comment (same line, or in the comment block
//!     directly above the statement).
//!   - **hot-lock**: no `Mutex` / `RwLock` in the hot-path modules
//!     (`exec/`, `algos/`, `core/`, `shard/`, `net/`) outside tests.
//!   - **hot-panic**: no `.unwrap()` / `.expect(` in hot-path modules
//!     outside tests.
//!   - **wallclock**: no `Instant::now` outside the measurement layer
//!     (`bench/`, `coordinator/`, `obs/`, `main.rs`, `cli.rs`).
//!   - **pub-doc**: every `pub` item in `exec/` carries a `///` rustdoc.
//!   - **wire-no-alloc-in-decode**: no `Vec::new` / `.to_vec()` /
//!     `vec!` in `net/wire.rs` outside tests — the framing layer reads
//!     zero-copy from `&[u8]`; containers are allocated one layer up in
//!     `net/proto.rs` where counts have been bounds-checked.
//!   - **obs-no-hot-alloc**: no growth calls (`.push(` / `.extend` /
//!     `.reserve(` / `.to_vec()` / `vec!` / `with_capacity`) inside
//!     the record-path functions of `obs/` files — any `fn` named
//!     `start` or `record*`. Recording a span or a histogram sample
//!     runs inside the phases being measured; an allocation there
//!     perturbs the very latency it reports. Construction and drain
//!     paths (`with_capacity`, `drain_into`, the tracer's master-lane
//!     spans) are outside those fns and stay free to allocate.
//!   - **session-read-no-lock**: no `Mutex` / `RwLock` / `.lock(`
//!     inside the function bodies of `session/snapshot.rs` outside
//!     tests (brace-counted, like `obs-no-hot-alloc`). An
//!     `EpochSnapshot` read is wait-free by contract — readers must
//!     never block on (or be blocked by) a committing writer, so no
//!     snapshot code path may acquire a lock.
//!   - **durable-decode-no-panic**: no `.unwrap()` / `.expect(` / bare
//!     `as` casts inside the record-decode fns of `durable/` — any
//!     `fn` named `decode*`, `read*`, or `scan*`. Those functions are
//!     fed bytes that crashed mid-write: torn, truncated, bit-flipped.
//!     Every length is attacker-ish input; recovery must reject bad
//!     tails with a clean error (or a tolerated-prefix scan), never a
//!     panic or a silent truncating cast.
//!
//!   Violations can be waived in place with a reason:
//!   `// xlint: allow(<rule>): <reason>` on the offending line or in the
//!   comment block directly above it, or
//!   `// xlint: allow-file(<rule>): <reason>` anywhere in the file.
//!
//! * `cargo run -p xtask -- bench-snapshot` — runs the quick bench
//!   workloads (same flags as CI), reports the `BENCH_*.json`
//!   artifacts they emit under `bench_results/`, and diffs each
//!   artifact's column header against the baseline from before the
//!   run — the committed `SCHEMA_<name>.json` files (header-only, no
//!   measurements) plus any pre-existing `BENCH_<name>.json`. A
//!   dropped column fails the snapshot (downstream tooling keys on
//!   columns by name); new columns and new artifacts are reported as
//!   informational drift.
//!
//! The lint is intentionally a line-oriented approximation, not a full
//! parser: sources are first masked (string/char literals blanked,
//! comments stripped into a side channel) so the rules only ever match
//! real code, and `#[cfg(test)] mod` regions are skipped by brace
//! counting.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// The nine lint rules. Names are what waivers reference.
const RULES: [&str; 9] = [
    "safety-comment",
    "hot-lock",
    "hot-panic",
    "wallclock",
    "pub-doc",
    "wire-no-alloc-in-decode",
    "obs-no-hot-alloc",
    "session-read-no-lock",
    "durable-decode-no-panic",
];

/// Hot-path module prefixes: lock-free by design, so locks and panics
/// in non-test code are lint errors there. `net/` joined when the
/// server core shipped — its IO and state threads synchronize purely
/// over channels.
const HOT_PREFIXES: [&str; 5] = ["exec/", "algos/", "core/", "shard/", "net/"];

/// The one file where decode-side allocation is banned outright (see
/// the `wire-no-alloc-in-decode` rule).
const WIRE_FILE: &str = "net/wire.rs";

/// Where `Instant::now` is legitimate: the measurement layer itself.
/// `obs/` joined when the tracing subsystem shipped — its clock seam
/// (`obs::clock`) is where every other module's timestamps come from.
const WALLCLOCK_ALLOW_PREFIXES: [&str; 3] = ["bench/", "coordinator/", "obs/"];
const WALLCLOCK_ALLOW_FILES: [&str; 2] = ["main.rs", "cli.rs"];

/// The observability tree, whose record-path fns must not allocate
/// (see the `obs-no-hot-alloc` rule).
const OBS_PREFIX: &str = "obs/";

/// The snapshot read path, whose fn bodies must never acquire a lock
/// (see the `session-read-no-lock` rule).
const SNAPSHOT_FILE: &str = "session/snapshot.rs";

/// The durability tree, whose record-decode fns must neither panic nor
/// truncate lengths with bare casts (see `durable-decode-no-panic`).
const DURABLE_PREFIX: &str = "durable/";

/// Growth calls banned inside `obs/` record-path fns: recording must
/// never resize a container, or tracing perturbs what it measures.
const OBS_GROWTH_TOKENS: [&str; 6] =
    [".push(", ".extend", ".reserve(", ".to_vec()", "vec!", "with_capacity"];

/// One lint finding, keyed by file-relative path and 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rust/src/{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.msg
        )
    }
}

/// A source line after masking: `code` has comments removed and all
/// string/char literal contents blanked; `comment` holds the comment
/// text that appeared on the line (including the `//` / `/*` markers).
#[derive(Debug, Default, Clone)]
struct MaskedLine {
    code: String,
    comment: String,
}

/// Lexer state for [`mask`]. Strings and block comments span lines.
enum MaskState {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    CharLit,
}

/// Split a source file into [`MaskedLine`]s: a small Rust lexer that
/// understands line/nested-block comments, string literals (including
/// raw strings and byte strings), char literals vs lifetimes, and
/// escape sequences. Literal contents are replaced by spaces so the
/// line-oriented rules never match inside them.
fn mask(src: &str) -> Vec<MaskedLine> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = MaskedLine::default();
    let mut state = MaskState::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, MaskState::LineComment) {
                state = MaskState::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        let next = chars.get(i + 1).copied();
        match state {
            MaskState::Code => {
                let prev_ident = cur
                    .code
                    .chars()
                    .next_back()
                    .is_some_and(|p| p.is_alphanumeric() || p == '_');
                match c {
                    '/' if next == Some('/') => {
                        state = MaskState::LineComment;
                        cur.comment.push_str("//");
                        i += 2;
                    }
                    '/' if next == Some('*') => {
                        state = MaskState::BlockComment(1);
                        cur.comment.push_str("/*");
                        i += 2;
                    }
                    '"' => {
                        state = MaskState::Str;
                        cur.code.push('"');
                        i += 1;
                    }
                    'r' | 'b' if !prev_ident => {
                        // Possible raw string r"…" / r#"…"#, byte string
                        // b"…", byte char b'…', or raw byte string br#"…"#.
                        let mut j = i + 1;
                        if c == 'b' && chars.get(j) == Some(&'r') {
                            j += 1;
                        }
                        let mut hashes = 0u32;
                        while chars.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        if chars.get(j) == Some(&'"') && (c == 'r' || j > i + 1 || hashes == 0) {
                            cur.code.extend(&chars[i..=j]);
                            state = if hashes > 0 || c == 'r' || chars.get(i + 1) == Some(&'r') {
                                MaskState::RawStr(hashes)
                            } else {
                                MaskState::Str
                            };
                            // Plain b"…" (no hashes, no r) is an escaped
                            // string; r-prefixed forms are raw.
                            if c == 'b' && chars.get(i + 1) == Some(&'"') {
                                state = MaskState::Str;
                            }
                            i = j + 1;
                        } else if c == 'b' && chars.get(i + 1) == Some(&'\'') {
                            cur.code.push('b');
                            cur.code.push('\'');
                            state = MaskState::CharLit;
                            i += 2;
                        } else {
                            cur.code.push(c);
                            i += 1;
                        }
                    }
                    '\'' => {
                        // Char literal vs lifetime: a literal is '\…' or
                        // 'X' (single char then a closing quote).
                        let is_char = next == Some('\\')
                            || (next.is_some() && chars.get(i + 2) == Some(&'\''));
                        cur.code.push('\'');
                        if is_char {
                            state = MaskState::CharLit;
                        }
                        i += 1;
                    }
                    _ => {
                        cur.code.push(c);
                        i += 1;
                    }
                }
            }
            MaskState::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            MaskState::BlockComment(depth) => {
                if c == '*' && next == Some('/') {
                    cur.comment.push_str("*/");
                    state = if depth == 1 {
                        MaskState::Code
                    } else {
                        MaskState::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    cur.comment.push_str("/*");
                    state = MaskState::BlockComment(depth + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            MaskState::Str => {
                if c == '\\' {
                    cur.code.push(' ');
                    if next.is_some() {
                        cur.code.push(' ');
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    cur.code.push('"');
                    state = MaskState::Code;
                    i += 1;
                } else {
                    cur.code.push(' ');
                    i += 1;
                }
            }
            MaskState::RawStr(hashes) => {
                if c == '"' {
                    let ok = (0..hashes as usize).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if ok {
                        cur.code.push('"');
                        for _ in 0..hashes {
                            cur.code.push('#');
                        }
                        state = MaskState::Code;
                        i += 1 + hashes as usize;
                    } else {
                        cur.code.push(' ');
                        i += 1;
                    }
                } else {
                    cur.code.push(' ');
                    i += 1;
                }
            }
            MaskState::CharLit => {
                if c == '\\' {
                    cur.code.push(' ');
                    if next.is_some() {
                        cur.code.push(' ');
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '\'' {
                    cur.code.push('\'');
                    state = MaskState::Code;
                    i += 1;
                } else {
                    cur.code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Mark the lines belonging to `#[cfg(test)]` items. For a
/// `#[cfg(test)] mod …` the whole brace-balanced region is marked; for
/// a single gated item the item body (or the `;`-terminated line) is.
fn test_regions(lines: &[MaskedLine]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let t = lines[i].code.trim();
        if !t.starts_with("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // Find the item the attribute attaches to (skip blank /
        // comment-only / further attribute lines).
        let mut j = i + 1;
        while j < lines.len() {
            let tj = lines[j].code.trim();
            if tj.is_empty() || tj.starts_with("#[") {
                j += 1;
            } else {
                break;
            }
        }
        if j >= lines.len() {
            break;
        }
        // Mark from the attribute through the end of the item: either
        // the matching close brace, or the first `;` before any brace.
        let mut depth = 0i32;
        let mut opened = false;
        let mut k = j;
        while k < lines.len() {
            for ch in lines[k].code.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if !opened && lines[k].code.trim_end().ends_with(';') {
                break;
            }
            if opened && depth <= 0 {
                break;
            }
            k += 1;
        }
        let end = k.min(lines.len() - 1);
        in_test[i..=end].fill(true);
        i = end + 1;
    }
    in_test
}

/// The identifier following `fn ` on a masked code line, if any
/// (`pub fn record_raw(` → `record_raw`). Left word boundary is
/// checked so identifiers merely ending in `fn` don't match.
fn fn_name(code: &str) -> Option<&str> {
    let at = code.find("fn ")?;
    if at > 0 {
        let b = code.as_bytes()[at - 1];
        if b.is_ascii_alphanumeric() || b == b'_' {
            return None;
        }
    }
    let rest = code[at + 3..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_alphanumeric() || c == '_'))
        .unwrap_or(rest.len());
    (end > 0).then(|| &rest[..end])
}

/// Mark the lines inside the bodies of functions whose name satisfies
/// `pred`: a region runs from the signature line through the matching
/// close brace (brace-counted, like [`test_regions`]; a trait
/// declaration ending in `;` before any brace covers just the
/// signature).
fn fn_regions(lines: &[MaskedLine], pred: impl Fn(&str) -> bool) -> Vec<bool> {
    let mut hot = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let is_record = fn_name(&lines[i].code).is_some_and(&pred);
        if !is_record {
            i += 1;
            continue;
        }
        let mut depth = 0i32;
        let mut opened = false;
        let mut k = i;
        while k < lines.len() {
            for ch in lines[k].code.chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if !opened && lines[k].code.trim_end().ends_with(';') {
                break;
            }
            if opened && depth <= 0 {
                break;
            }
            k += 1;
        }
        let end = k.min(lines.len() - 1);
        hot[i..=end].fill(true);
        i = end + 1;
    }
    hot
}

/// Record-path fn bodies for the `obs-no-hot-alloc` rule: any `fn`
/// named `start` or `record*` — the per-event hot functions of the
/// tracing layer.
fn record_fn_regions(lines: &[MaskedLine]) -> Vec<bool> {
    fn_regions(lines, |n| n == "start" || n.starts_with("record"))
}

/// Gather the comment context for a violation at `i`: the same-line
/// comment plus the comment block directly above. The walk tolerates a
/// few non-terminated code lines so the head of a multi-line statement
/// doesn't cut the block off, but stops at blank lines and at lines
/// that end a statement (`;`, `{`, `}`).
fn comment_context(lines: &[MaskedLine], i: usize) -> String {
    let mut ctx = lines[i].comment.clone();
    let mut continuation_budget = 4;
    let mut j = i;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        let comment = lines[j].comment.trim();
        if code.is_empty() && comment.is_empty() {
            break;
        }
        if code.is_empty() || code.starts_with("#[") {
            ctx.push('\n');
            ctx.push_str(comment);
            continue;
        }
        let ends_stmt =
            code.ends_with(';') || code.ends_with('{') || code.ends_with('}') || code.ends_with(',');
        if ends_stmt || continuation_budget == 0 {
            break;
        }
        continuation_budget -= 1;
        if !comment.is_empty() {
            ctx.push('\n');
            ctx.push_str(comment);
        }
    }
    ctx
}

/// True if `code` contains `word` as a standalone identifier (not a
/// substring of a longer identifier like `MutexGuard`… which *does*
/// start with `Mutex` — boundaries are checked on both sides).
fn word_in(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || {
            let b = bytes[at - 1];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        let end = at + word.len();
        let after_ok = end >= bytes.len() || {
            let b = bytes[end];
            !(b.is_ascii_alphanumeric() || b == b'_')
        };
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

/// True if the line-scoped waiver `// xlint: allow(<rule>): reason`
/// appears in the comment context of line `i`.
fn line_waived(lines: &[MaskedLine], i: usize, rule: &str) -> bool {
    comment_context(lines, i).contains(&format!("xlint: allow({rule})"))
}

/// Collect the rules waived for the whole file via
/// `// xlint: allow-file(<rule>): reason`.
fn file_waivers(lines: &[MaskedLine]) -> Vec<&'static str> {
    let mut out = Vec::new();
    for l in lines {
        for rule in RULES {
            if l.comment.contains(&format!("xlint: allow-file({rule})")) && !out.contains(&rule) {
                out.push(rule);
            }
        }
    }
    out
}

/// True if the `pub` item starting at line `i` has a rustdoc comment
/// directly above it (attribute lines and plain comments in between are
/// skipped; any other code line or a blank line ends the search).
fn has_rustdoc(lines: &[MaskedLine], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        let comment = lines[j].comment.trim();
        if code.starts_with("#[") {
            continue;
        }
        if code.is_empty() {
            if comment.starts_with("///") || comment.starts_with("/**") {
                return true;
            }
            if comment.is_empty() {
                return false;
            }
            continue;
        }
        return false;
    }
    false
}

/// Lint one file. `rel` is the path relative to `rust/src` with `/`
/// separators (e.g. `exec/radix.rs`) — rule applicability keys off it.
fn lint_file(rel: &str, src: &str) -> Vec<Violation> {
    let lines = mask(src);
    let in_test = test_regions(&lines);
    let waived_file = file_waivers(&lines);
    let is_hot = HOT_PREFIXES.iter().any(|p| rel.starts_with(p));
    let wallclock_ok = WALLCLOCK_ALLOW_PREFIXES.iter().any(|p| rel.starts_with(p))
        || WALLCLOCK_ALLOW_FILES.contains(&rel);
    let wants_pub_doc = rel.starts_with("exec/");
    let is_obs = rel.starts_with(OBS_PREFIX);
    let record_hot = if is_obs {
        record_fn_regions(&lines)
    } else {
        Vec::new()
    };
    let is_snapshot = rel == SNAPSHOT_FILE;
    // Every fn in the snapshot file is a read-path fn: the type's whole
    // surface is reads over immutable refcounted state.
    let snapshot_fns = if is_snapshot {
        fn_regions(&lines, |_| true)
    } else {
        Vec::new()
    };
    let is_durable = rel.starts_with(DURABLE_PREFIX);
    let durable_decode = if is_durable {
        fn_regions(&lines, |n| {
            n.starts_with("decode") || n.starts_with("read") || n.starts_with("scan")
        })
    } else {
        Vec::new()
    };

    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, msg: String| {
        if waived_file.contains(&rule) || line_waived(&lines, line, rule) {
            return;
        }
        out.push(Violation {
            file: rel.to_string(),
            line: line + 1,
            rule,
            msg,
        });
    };

    for (i, l) in lines.iter().enumerate() {
        let code = l.code.as_str();
        let trimmed = code.trim();

        // safety-comment: applies everywhere, tests included — unsafe
        // is unsafe regardless of where it runs.
        if word_in(code, "unsafe") {
            // Skip declarations: `unsafe fn` / `unsafe trait` /
            // `unsafe extern` document their contract in rustdoc
            // (`# Safety`), which clippy::missing_safety_doc enforces.
            let after = code
                .split("unsafe")
                .nth(1)
                .map(str::trim_start)
                .unwrap_or("");
            let is_decl = after.starts_with("fn ")
                || after.starts_with("fn(")
                || after.starts_with("trait ")
                || after.starts_with("extern ");
            if !is_decl && !comment_context(&lines, i).contains("SAFETY:") {
                push(
                    i,
                    "safety-comment",
                    "`unsafe` without an adjacent `// SAFETY:` comment".to_string(),
                );
            }
        }

        if is_hot && !in_test[i] {
            for lock in ["Mutex", "RwLock"] {
                if word_in(code, lock) {
                    push(
                        i,
                        "hot-lock",
                        format!("`{lock}` in hot-path module `{rel}` (hot paths are lock-free by design)"),
                    );
                }
            }
            for panicky in [".unwrap()", ".expect("] {
                if code.contains(panicky) {
                    push(
                        i,
                        "hot-panic",
                        format!("`{panicky}` in hot-path module `{rel}` (recover or propagate instead)"),
                    );
                }
            }
        }

        if rel == WIRE_FILE && !in_test[i] {
            for (alloc, found) in [
                ("Vec::new", word_in(code, "Vec") && code.contains("Vec::new")),
                (".to_vec()", code.contains(".to_vec()")),
                ("vec!", code.contains("vec!")),
            ] {
                if found {
                    push(
                        i,
                        "wire-no-alloc-in-decode",
                        format!(
                            "`{alloc}` in {WIRE_FILE} (framing is zero-copy; allocate in net/proto.rs \
                             after bounds checks)"
                        ),
                    );
                }
            }
        }

        if is_obs && !in_test[i] && record_hot[i] {
            for growth in OBS_GROWTH_TOKENS {
                if code.contains(growth) {
                    push(
                        i,
                        "obs-no-hot-alloc",
                        format!(
                            "`{growth}` inside an obs/ record-path fn (record/start must stay \
                             allocation-free so tracing never perturbs what it measures)"
                        ),
                    );
                }
            }
        }

        if is_snapshot && !in_test[i] && snapshot_fns[i] {
            let locky = ["Mutex", "RwLock"]
                .iter()
                .find(|w| word_in(code, w))
                .copied()
                .or_else(|| code.contains(".lock(").then_some(".lock("));
            if let Some(tok) = locky {
                push(
                    i,
                    "session-read-no-lock",
                    format!(
                        "`{tok}` inside a {SNAPSHOT_FILE} fn (snapshot reads are wait-free by \
                         contract — they must never acquire a lock)"
                    ),
                );
            }
        }

        if is_durable && !in_test[i] && durable_decode[i] {
            for panicky in [".unwrap()", ".expect("] {
                if code.contains(panicky) {
                    push(
                        i,
                        "durable-decode-no-panic",
                        format!(
                            "`{panicky}` inside a durable/ record-decode fn (crash-torn input \
                             must yield an error or a tolerated prefix, never a panic)"
                        ),
                    );
                }
            }
            if word_in(code, "as") {
                push(
                    i,
                    "durable-decode-no-panic",
                    "bare `as` cast inside a durable/ record-decode fn (use `try_from`/`try_into` \
                     so corrupt lengths fail instead of truncating)"
                        .to_string(),
                );
            }
        }

        if !wallclock_ok && !in_test[i] && code.contains("Instant::now") {
            push(
                i,
                "wallclock",
                "`Instant::now` outside the measurement layer (bench/, coordinator/, obs/, \
                 main.rs, cli.rs)"
                    .to_string(),
            );
        }

        if wants_pub_doc && !in_test[i] {
            if let Some(rest) = trimmed.strip_prefix("pub ") {
                let rest = rest.trim_start();
                let kinds = [
                    "fn ", "struct ", "enum ", "const ", "static ", "trait ", "type ", "union ",
                    "unsafe fn ",
                ];
                if kinds.iter().any(|k| rest.starts_with(k)) && !has_rustdoc(&lines, i) {
                    push(
                        i,
                        "pub-doc",
                        format!("undocumented `pub` item in exec/: `{trimmed}`"),
                    );
                }
            }
        }
    }
    out
}

/// Recursively collect `.rs` files under `root`, sorted for stable
/// output.
fn rust_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `src_root` (normally `rust/src`).
fn lint_tree(src_root: &Path) -> Result<Vec<Violation>, String> {
    let files = rust_files(src_root)
        .map_err(|e| format!("cannot walk {}: {e}", src_root.display()))?;
    if files.is_empty() {
        return Err(format!("no .rs files under {}", src_root.display()));
    }
    let mut all = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(src_root)
            .map_err(|e| e.to_string())?
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src =
            fs::read_to_string(&path).map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        all.extend(lint_file(&rel, &src));
    }
    Ok(all)
}

/// Repo root: the xtask manifest dir's parent.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level below the repo root")
        .to_path_buf()
}

fn run_lint(args: &[String]) -> ExitCode {
    let src_root = match args {
        [] => repo_root().join("rust/src"),
        [flag, path] if flag == "--root" => PathBuf::from(path),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [--root <src-dir>]");
            return ExitCode::from(2);
        }
    };
    match lint_tree(&src_root) {
        Ok(violations) if violations.is_empty() => {
            println!("xtask lint: clean ({})", src_root.display());
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                println!("{v}");
            }
            println!(
                "xtask lint: {} violation(s). Waive with `// xlint: allow(<rule>): <reason>`.",
                violations.len()
            );
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("xtask lint: {e}");
            ExitCode::from(2)
        }
    }
}

/// Quick bench configurations — the same flags CI's smoke steps use, so
/// a local snapshot is comparable to the CI artifact.
const SNAPSHOT_BENCHES: [(&str, &[&str]); 7] = [
    ("abl_session", &["--quick", "--n", "10k", "--epochs", "2"]),
    ("abl_shard", &["--quick", "--n", "6k", "--epochs", "2"]),
    ("abl_nd", &["--quick"]),
    ("abl_sort", &["--quick"]),
    ("abl_net", &["--quick"]),
    ("abl_rw", &["--quick"]),
    ("abl_wal", &["--quick"]),
];

/// Pull the `"header"` column list out of a `BENCH_*.json` artifact
/// (written by `Table::write_json`). Tolerant string scan — the
/// workspace carries no JSON parser, and header cells never contain
/// brackets or escaped quotes.
fn json_header(s: &str) -> Option<Vec<String>> {
    let at = s.find("\"header\"")?;
    let open = s[at..].find('[')? + at;
    let close = s[open..].find(']')? + open;
    let cells = s[open + 1..close]
        .split('"')
        .skip(1)
        .step_by(2)
        .map(str::to_string)
        .collect();
    Some(cells)
}

/// The bench name a `bench_results/` artifact refers to:
/// `BENCH_abl_net.json` and `SCHEMA_abl_net.json` both key `abl_net`,
/// so a fresh measurement diffs against the committed schema baseline.
fn artifact_key(file_name: &str) -> String {
    file_name
        .trim_start_matches("BENCH_")
        .trim_start_matches("SCHEMA_")
        .trim_end_matches(".json")
        .to_string()
}

/// Map of bench name → (path, header columns) across the candidate
/// `bench_results/` dirs. `BENCH_*` measurements win over `SCHEMA_*`
/// baselines for the same bench (`include_schema` is how the baseline
/// pass picks the committed schema up when no measurement exists yet);
/// unparseable files map to an empty header rather than being skipped,
/// so they still show up in the diff.
fn collect_headers(
    dirs: &[PathBuf],
    include_schema: bool,
) -> std::collections::BTreeMap<String, (PathBuf, Vec<String>)> {
    let mut out = std::collections::BTreeMap::new();
    let mut schemas = Vec::new();
    for dir in dirs {
        if let Ok(entries) = fs::read_dir(dir) {
            for entry in entries.flatten() {
                let p = entry.path();
                if !p.extension().is_some_and(|e| e == "json") {
                    continue;
                }
                let name = p
                    .file_name()
                    .map(|n| n.to_string_lossy().into_owned())
                    .unwrap_or_default();
                let header = fs::read_to_string(&p)
                    .ok()
                    .and_then(|s| json_header(&s))
                    .unwrap_or_default();
                if name.starts_with("SCHEMA_") {
                    if include_schema {
                        schemas.push((artifact_key(&name), (p, header)));
                    }
                } else {
                    out.insert(artifact_key(&name), (p, header));
                }
            }
        }
    }
    for (key, val) in schemas {
        out.entry(key).or_insert(val);
    }
    out
}

fn run_bench_snapshot() -> ExitCode {
    let root = repo_root();
    // Benches emit BENCH_*.json into bench_results/ relative to their
    // working dir; the baseline is the committed SCHEMA_*.json files
    // plus whatever BENCH_*.json measurements predate this run.
    let dirs = [root.join("bench_results"), root.join("rust/bench_results")];
    let baseline = collect_headers(&dirs, true);
    let mut failed = false;
    for (bench, flags) in SNAPSHOT_BENCHES {
        println!("xtask bench-snapshot: cargo bench --bench {bench} -- {}", flags.join(" "));
        let status = std::process::Command::new("cargo")
            .arg("bench")
            .arg("--bench")
            .arg(bench)
            .arg("--")
            .args(flags)
            .current_dir(&root)
            .status();
        match status {
            Ok(s) if s.success() => {}
            Ok(s) => {
                eprintln!("xtask bench-snapshot: {bench} exited with {s}");
                failed = true;
            }
            Err(e) => {
                eprintln!("xtask bench-snapshot: cannot launch cargo: {e}");
                failed = true;
            }
        }
    }
    let current = collect_headers(&dirs, false);
    if current.is_empty() {
        eprintln!("xtask bench-snapshot: no bench_results/*.json artifacts found");
        failed = true;
    } else {
        println!("xtask bench-snapshot: artifacts:");
        for (name, (path, header)) in &current {
            match baseline.get(name) {
                None => println!("  {} (new; {} columns)", path.display(), header.len()),
                Some((_, base)) if base == header => {
                    println!("  {} (schema unchanged)", path.display());
                }
                Some((_, base)) => {
                    let lost: Vec<&String> =
                        base.iter().filter(|c| !header.contains(c)).collect();
                    let gained: Vec<&String> =
                        header.iter().filter(|c| !base.contains(c)).collect();
                    println!(
                        "  {} (schema drift: lost {lost:?}, gained {gained:?})",
                        path.display()
                    );
                    if !lost.is_empty() {
                        eprintln!(
                            "xtask bench-snapshot: {name} dropped column(s) {lost:?} — \
                             downstream tooling keys on columns by name"
                        );
                        failed = true;
                    }
                }
            }
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.split_first() {
        Some((cmd, rest)) if cmd == "lint" => run_lint(rest),
        Some((cmd, rest)) if cmd == "bench-snapshot" && rest.is_empty() => run_bench_snapshot(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- <lint [--root <src-dir>] | bench-snapshot>");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    // ---- masking -------------------------------------------------

    #[test]
    fn mask_blanks_strings_and_strips_comments() {
        let src = "let s = \".unwrap() // not code\"; // real .unwrap() comment\n";
        let lines = mask(src);
        assert_eq!(lines.len(), 1);
        assert!(!lines[0].code.contains(".unwrap()"), "{:?}", lines[0].code);
        assert!(lines[0].comment.contains("real .unwrap() comment"));
    }

    #[test]
    fn mask_handles_escapes_and_char_literals() {
        let src = "let c = '\\''; let q = '\"'; let s = \"a\\\"b\"; x.unwrap();\n";
        let lines = mask(src);
        assert!(lines[0].code.contains(".unwrap()"));
        // The double quote hidden inside the char literal must not open
        // a string that would swallow the rest of the line.
        assert!(lines[0].code.contains("let s ="));
    }

    #[test]
    fn mask_keeps_lifetimes_out_of_char_state() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x } y.unwrap();\n";
        let lines = mask(src);
        assert!(lines[0].code.contains(".unwrap()"));
    }

    #[test]
    fn mask_handles_raw_strings() {
        let src = "let r = r#\"unsafe { \"quoted\" }\"#; z.unwrap();\n";
        let lines = mask(src);
        assert!(!word_in(&lines[0].code, "unsafe"));
        assert!(lines[0].code.contains(".unwrap()"));
    }

    #[test]
    fn mask_handles_nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ a.unwrap();\n";
        let lines = mask(src);
        assert!(lines[0].code.contains(".unwrap()"));
        assert!(!lines[0].code.contains("still comment"));
    }

    #[test]
    fn mask_multiline_string_stays_masked() {
        let src = "let s = \"line one\nunsafe { boo }\n\"; b.unwrap();\n";
        let lines = mask(src);
        assert!(!word_in(&lines[1].code, "unsafe"));
        assert!(lines[2].code.contains(".unwrap()"));
    }

    // ---- safety-comment ------------------------------------------

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let src = "fn f(p: *mut u8) {\n    unsafe { *p = 0 };\n}\n";
        let vs = lint_file("algos/x.rs", src);
        assert_eq!(rules_of(&vs), ["safety-comment"]);
        assert_eq!(vs[0].line, 2);
    }

    #[test]
    fn unsafe_with_safety_comment_above_passes() {
        let src = "fn f(p: *mut u8) {\n    // SAFETY: p is valid for writes.\n    unsafe { *p = 0 };\n}\n";
        assert!(lint_file("algos/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_with_same_line_safety_comment_passes() {
        let src = "fn f(p: *mut u8) {\n    unsafe { *p = 0 }; // SAFETY: p is valid.\n}\n";
        assert!(lint_file("algos/x.rs", src).is_empty());
    }

    #[test]
    fn safety_comment_survives_multiline_statement_head() {
        let src = "fn f(p: *mut u8) {\n    // SAFETY: laundering is fine here.\n    let q: *mut u8 =\n        unsafe { p.add(1) };\n}\n";
        assert!(lint_file("algos/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_fn_declaration_is_not_flagged() {
        let src = "/// Docs.\n///\n/// # Safety\n/// Caller checks bounds.\npub unsafe fn g(p: *mut u8) {\n    // SAFETY: contract forwarded from caller.\n    unsafe { *p = 0 };\n}\n";
        assert!(lint_file("algos/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_impl_requires_safety_comment() {
        let src = "struct W(*mut u8);\nunsafe impl Send for W {}\n";
        let vs = lint_file("core/x.rs", src);
        assert_eq!(rules_of(&vs), ["safety-comment"]);
    }

    #[test]
    fn unsafe_in_tests_still_needs_safety_comment() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        unsafe { std::hint::unreachable_unchecked() };\n    }\n}\n";
        let vs = lint_file("algos/x.rs", src);
        assert_eq!(rules_of(&vs), ["safety-comment"]);
    }

    // ---- hot-lock ------------------------------------------------

    #[test]
    fn mutex_in_hot_module_is_flagged() {
        let src = "use std::sync::Mutex;\n";
        for rel in ["exec/a.rs", "algos/a.rs", "core/a.rs", "shard/a.rs"] {
            let vs = lint_file(rel, src);
            assert_eq!(rules_of(&vs), ["hot-lock"], "{rel}");
        }
    }

    #[test]
    fn mutex_outside_hot_modules_is_fine() {
        let src = "use std::sync::{Mutex, RwLock};\n";
        assert!(lint_file("hla/a.rs", src).is_empty());
        assert!(lint_file("engine.rs", src).is_empty());
    }

    #[test]
    fn rwlock_is_flagged_but_mutexguard_alone_is_not() {
        let vs = lint_file("exec/a.rs", "use std::sync::RwLock;\n");
        assert_eq!(rules_of(&vs), ["hot-lock"]);
        // `MutexGuard` as a bare identifier is not `Mutex`.
        assert!(lint_file("exec/a.rs", "fn f(g: MutexGuard<u32>) {}\n").is_empty());
    }

    #[test]
    fn hot_lock_in_test_mod_is_fine() {
        let src = "#[cfg(test)]\nmod tests {\n    use std::sync::Mutex;\n}\n";
        assert!(lint_file("core/a.rs", src).is_empty());
    }

    // ---- hot-panic -----------------------------------------------

    #[test]
    fn unwrap_and_expect_in_hot_module_are_flagged() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\nfn g(x: Option<u32>) -> u32 {\n    x.expect(\"present\")\n}\n";
        let vs = lint_file("shard/a.rs", src);
        assert_eq!(rules_of(&vs), ["hot-panic", "hot-panic"]);
    }

    #[test]
    fn unwrap_variants_are_not_flagged() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap_or_else(|| 0) + x.unwrap_or_default()\n}\n";
        assert!(lint_file("shard/a.rs", src).is_empty());
    }

    #[test]
    fn unwrap_in_test_mod_is_fine() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1).unwrap();\n    }\n}\n";
        assert!(lint_file("exec/a.rs", src).is_empty());
    }

    #[test]
    fn unwrap_in_doc_comment_is_fine() {
        let src = "/// Call `.unwrap()` on the result.\nfn f() {}\n";
        assert!(lint_file("exec/a.rs", src).is_empty());
    }

    // ---- wallclock -----------------------------------------------

    #[test]
    fn instant_now_outside_measurement_layer_is_flagged() {
        let src = "fn f() {\n    let t = std::time::Instant::now();\n    drop(t);\n}\n";
        let vs = lint_file("algos/a.rs", src);
        assert_eq!(rules_of(&vs), ["wallclock"]);
        assert!(lint_file("bench/a.rs", src).is_empty());
        assert!(lint_file("coordinator/a.rs", src).is_empty());
        assert!(lint_file("obs/clock.rs", src).is_empty());
        assert!(lint_file("main.rs", src).is_empty());
        assert!(lint_file("cli.rs", src).is_empty());
    }

    // ---- pub-doc -------------------------------------------------

    #[test]
    fn undocumented_pub_item_in_exec_is_flagged() {
        let src = "pub fn undocumented() {}\n";
        let vs = lint_file("exec/a.rs", src);
        assert_eq!(rules_of(&vs), ["pub-doc"]);
        // Outside exec/ the rule does not apply.
        assert!(lint_file("algos/a.rs", src).is_empty());
    }

    #[test]
    fn documented_pub_item_passes() {
        let src = "/// Does the thing.\npub fn documented() {}\n";
        assert!(lint_file("exec/a.rs", src).is_empty());
    }

    #[test]
    fn doc_separated_by_attribute_still_counts() {
        let src = "/// Docs here.\n#[inline]\npub fn fast() {}\n";
        assert!(lint_file("exec/a.rs", src).is_empty());
    }

    #[test]
    fn pub_use_and_pub_crate_are_not_linted() {
        let src = "pub use foo::Bar;\npub(crate) fn helper() {}\npub mod sub;\n";
        assert!(lint_file("exec/a.rs", src).is_empty());
    }

    #[test]
    fn pub_struct_needs_doc_too() {
        let src = "pub struct Bare {\n    pub field: u32,\n}\n";
        let vs = lint_file("exec/a.rs", src);
        // `pub field: u32` is not an item-kind start, so only the
        // struct itself is flagged.
        assert_eq!(rules_of(&vs), ["pub-doc"]);
        assert_eq!(vs[0].line, 1);
    }

    // ---- wire-no-alloc-in-decode ---------------------------------

    #[test]
    fn alloc_in_wire_file_is_flagged() {
        let src = "fn a() { let v: Vec<u8> = Vec::new(); drop(v); }\nfn b(s: &[u8]) -> Vec<u8> { s.to_vec() }\nfn c() { let v = vec![1u8]; drop(v); }\n";
        let vs = lint_file("net/wire.rs", src);
        assert_eq!(
            rules_of(&vs),
            [
                "wire-no-alloc-in-decode",
                "wire-no-alloc-in-decode",
                "wire-no-alloc-in-decode"
            ]
        );
    }

    #[test]
    fn alloc_outside_wire_file_is_fine() {
        let src = "fn a() -> Vec<u8> { Vec::new() }\n";
        assert!(lint_file("net/proto.rs", src).is_empty());
        assert!(lint_file("net/server.rs", src).is_empty());
    }

    #[test]
    fn alloc_in_wire_test_mod_is_fine() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let v = vec![0u8; 4];\n        assert_eq!(v.to_vec(), Vec::new().iter().chain(&v).copied().collect::<Vec<u8>>());\n    }\n}\n";
        assert!(lint_file("net/wire.rs", src).is_empty());
    }

    #[test]
    fn wire_alloc_waiver_works() {
        let src = "fn a() -> Vec<u8> {\n    // xlint: allow(wire-no-alloc-in-decode): encode side, caller owns the Vec.\n    Vec::new()\n}\n";
        assert!(lint_file("net/wire.rs", src).is_empty());
    }

    // ---- obs-no-hot-alloc ----------------------------------------

    #[test]
    fn fn_name_extracts_identifiers() {
        assert_eq!(fn_name("    pub fn record_raw(&mut self) {"), Some("record_raw"));
        assert_eq!(fn_name("fn start(&self) -> u64 {"), Some("start"));
        assert_eq!(fn_name("    pub(crate) fn record(&mut self, ns: u64) {"), Some("record"));
        assert_eq!(fn_name("let fnord = 3;"), None);
        assert_eq!(fn_name("call_fn (x)"), None);
        assert_eq!(fn_name(""), None);
    }

    #[test]
    fn growth_in_obs_record_fn_is_flagged() {
        let src = "impl SpanSink {\n    pub fn record(&mut self, rec: SpanRecord) {\n        self.records.push(rec);\n    }\n}\n";
        let vs = lint_file("obs/trace.rs", src);
        assert_eq!(rules_of(&vs), ["obs-no-hot-alloc"]);
        assert_eq!(vs[0].line, 3);
    }

    #[test]
    fn each_growth_token_is_caught_in_record_fns() {
        for bad in [
            "self.buf.push(rec);",
            "self.buf.extend_from_slice(&[rec]);",
            "self.buf.reserve(1);",
            "let _ = self.buf.to_vec();",
            "let _ = vec![0u8];",
            "let _ = Vec::<u8>::with_capacity(4);",
        ] {
            let src = format!("fn record_raw(&mut self, rec: u8) {{\n    {bad}\n}}\n");
            let vs = lint_file("obs/trace.rs", &src);
            assert_eq!(rules_of(&vs), ["obs-no-hot-alloc"], "{bad}");
        }
    }

    #[test]
    fn cursor_fill_record_path_is_clean() {
        // The real SpanSink shape: bounds-checked cursor fill, drop
        // counter on overflow — no growth calls anywhere.
        let src = "impl SpanSink {\n    #[inline]\n    pub fn record_raw(&mut self, rec: SpanRecord) {\n        if self.len < self.buf.len() {\n            self.buf[self.len] = rec;\n            self.len += 1;\n        } else {\n            self.dropped += 1;\n        }\n    }\n    pub fn start(&self) -> u64 {\n        if self.enabled { 7 } else { 0 }\n    }\n}\n";
        assert!(lint_file("obs/trace.rs", src).is_empty());
    }

    #[test]
    fn growth_outside_record_fns_in_obs_is_fine() {
        // Construction and drain paths allocate legitimately.
        let src = "pub fn with_capacity(cap: usize) -> Self {\n    let buf = vec![0u8; cap];\n    Self { buf, len: 0 }\n}\npub fn drain_into(&mut self, out: &mut Vec<u8>) {\n    out.extend_from_slice(&self.buf[..self.len]);\n    self.len = 0;\n}\n";
        assert!(lint_file("obs/trace.rs", src).is_empty());
    }

    #[test]
    fn record_fn_growth_outside_obs_is_not_linted() {
        let src = "fn record(&mut self, x: u32) {\n    self.log.push(x);\n}\n";
        assert!(lint_file("coordinator/metrics.rs", src).is_empty());
        assert!(lint_file("hla/a.rs", src).is_empty());
    }

    #[test]
    fn growth_in_obs_record_test_mod_is_fine() {
        let src = "#[cfg(test)]\nmod tests {\n    fn record_helper(v: &mut Vec<u8>) {\n        v.push(0);\n    }\n}\n";
        assert!(lint_file("obs/trace.rs", src).is_empty());
    }

    #[test]
    fn obs_alloc_waiver_works() {
        let src = "fn record(&mut self, x: u32) {\n    // xlint: allow(obs-no-hot-alloc): cold bootstrap path, runs once.\n    self.log.push(x);\n}\n";
        assert!(lint_file("obs/trace.rs", src).is_empty());
    }

    #[test]
    fn multiline_record_signature_is_covered() {
        let src = "pub fn record(\n    &mut self,\n    rec: SpanRecord,\n) {\n    self.records.push(rec);\n}\n";
        let vs = lint_file("obs/trace.rs", src);
        assert_eq!(rules_of(&vs), ["obs-no-hot-alloc"]);
        assert_eq!(vs[0].line, 5);
    }

    // ---- session-read-no-lock ------------------------------------

    #[test]
    fn lock_acquisition_in_snapshot_fn_is_flagged() {
        for bad in [
            "let g: std::sync::MutexGuard<u32> = m.lock().unwrap();",
            "let m = std::sync::Mutex::new(0u32);",
            "let l: &RwLock<u32> = lock;",
        ] {
            let src = format!("pub fn pairs(&self) -> Vec<u32> {{\n    {bad}\n    Vec::new()\n}}\n");
            let vs = lint_file("session/snapshot.rs", &src);
            assert_eq!(rules_of(&vs), ["session-read-no-lock"], "{bad}");
            assert_eq!(vs[0].line, 2, "{bad}");
        }
    }

    #[test]
    fn lock_in_snapshot_signature_is_flagged_too() {
        let src = "pub fn merge(&self, other: &Mutex<Snap>) -> Snap {\n    todo!()\n}\n";
        let vs = lint_file("session/snapshot.rs", src);
        assert_eq!(rules_of(&vs), ["session-read-no-lock"]);
    }

    #[test]
    fn snapshot_rule_does_not_apply_elsewhere_in_session() {
        // session/mod.rs (the writer side) may lock; only the snapshot
        // read path is lock-free by contract.
        let src = "fn drain(&mut self) {\n    let _g = self.m.lock().unwrap();\n}\n";
        assert!(lint_file("session/mod.rs", src).is_empty());
        assert!(lint_file("session/ingest.rs", src).is_empty());
    }

    #[test]
    fn lock_in_snapshot_test_mod_is_fine() {
        let src = "#[cfg(test)]\nmod tests {\n    fn helper() {\n        let m = std::sync::Mutex::new(0u32);\n        let _ = m.lock().unwrap();\n    }\n}\n";
        assert!(lint_file("session/snapshot.rs", src).is_empty());
    }

    #[test]
    fn snapshot_lock_waiver_works() {
        let src = "pub fn pairs(&self) -> Vec<u32> {\n    // xlint: allow(session-read-no-lock): cold diagnostics path.\n    let _g = self.m.lock().unwrap();\n    Vec::new()\n}\n";
        assert!(lint_file("session/snapshot.rs", src).is_empty());
    }

    #[test]
    fn snapshot_use_outside_fn_bodies_is_not_flagged() {
        // The rule brace-counts fn bodies: a (hypothetical) import line
        // acquires nothing, so it is not a violation by itself.
        let src = "use std::sync::Arc;\npub fn epoch(&self) -> u64 {\n    self.inner.epoch\n}\n";
        assert!(lint_file("session/snapshot.rs", src).is_empty());
    }

    // ---- durable-decode-no-panic ---------------------------------

    #[test]
    fn panicky_decode_in_durable_is_flagged() {
        let src = "fn decode_record(buf: &[u8]) -> u64 {\n    let n = buf.len() as u64;\n    let first = buf.first().copied().unwrap();\n    n + u64::from(first)\n}\n";
        let vs = lint_file("durable/wal.rs", src);
        assert_eq!(
            rules_of(&vs),
            ["durable-decode-no-panic", "durable-decode-no-panic"]
        );
        assert_eq!(vs[0].line, 2);
        assert_eq!(vs[1].line, 3);
    }

    #[test]
    fn non_decode_durable_fn_may_unwrap() {
        // The rule scopes to record-decode fns: setup/teardown paths in
        // durable/ answer to the ordinary panic policy, not this one.
        let src = "fn install(path: &std::path::Path) {\n    std::fs::remove_file(path).unwrap();\n}\n";
        assert!(lint_file("durable/wal.rs", src).is_empty());
    }

    #[test]
    fn decode_fn_outside_durable_is_not_this_rules_business() {
        let src = "fn decode_header(buf: &[u8]) -> u64 {\n    buf.len() as u64\n}\n";
        assert!(lint_file("hla/a.rs", src).is_empty());
    }

    #[test]
    fn durable_decode_waiver_works() {
        let src = "fn scan_tail(buf: &[u8]) -> usize {\n    // xlint: allow(durable-decode-no-panic): index bounded by the caller.\n    buf.len() as usize\n}\n";
        assert!(lint_file("durable/log.rs", src).is_empty());
    }

    #[test]
    fn durable_decode_ident_boundaries_do_not_trip_as() {
        // `as_ref`/`as_bytes` contain the letters but not the cast.
        let src = "fn read_magic(buf: &[u8]) -> bool {\n    buf.first().map(u8::to_owned).is_some() && !buf.as_ref().is_empty()\n}\n";
        assert!(lint_file("durable/snapshot.rs", src).is_empty());
    }

    // ---- bench-snapshot header diff ------------------------------

    #[test]
    fn artifact_key_strips_prefixes_and_extension() {
        assert_eq!(artifact_key("BENCH_abl_net.json"), "abl_net");
        assert_eq!(artifact_key("SCHEMA_abl_net.json"), "abl_net");
        assert_eq!(artifact_key("abl_sort_warm.json"), "abl_sort_warm");
    }

    #[test]
    fn schema_baselines_cover_every_snapshot_bench() {
        // Each quick smoke workload must have a committed header
        // baseline for the post-run diff to compare against.
        let dir = repo_root().join("bench_results");
        for (bench, _) in SNAPSHOT_BENCHES {
            let p = dir.join(format!("SCHEMA_{bench}.json"));
            let src = fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("missing schema baseline {}: {e}", p.display()));
            let header = json_header(&src)
                .unwrap_or_else(|| panic!("{} has no header array", p.display()));
            assert!(!header.is_empty(), "{} header is empty", p.display());
            assert!(
                src.contains("\"rows\": []"),
                "{} is a schema baseline and must not carry measurement rows",
                p.display()
            );
        }
    }

    #[test]
    fn json_header_reads_table_json() {
        let s = "{\"fig\": \"abl_net\", \"header\": [\"conns\", \"ops/s\", \"p99\"], \"rows\": [[1, 2]]}";
        assert_eq!(
            json_header(s),
            Some(vec!["conns".to_string(), "ops/s".to_string(), "p99".to_string()])
        );
        assert_eq!(json_header("{\"header\": []}"), Some(Vec::new()));
        assert_eq!(json_header("{}"), None);
        assert_eq!(json_header("not json at all"), None);
    }

    // ---- waivers -------------------------------------------------

    #[test]
    fn line_waiver_suppresses_a_single_violation() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // xlint: allow(hot-panic): construction-time only.\n    x.unwrap()\n}\nfn g(y: Option<u32>) -> u32 {\n    y.unwrap()\n}\n";
        let vs = lint_file("exec/a.rs", src);
        assert_eq!(rules_of(&vs), ["hot-panic"]);
        assert_eq!(vs[0].line, 6);
    }

    #[test]
    fn same_line_waiver_works() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap() // xlint: allow(hot-panic): justified here.\n}\n";
        assert!(lint_file("exec/a.rs", src).is_empty());
    }

    #[test]
    fn file_waiver_suppresses_the_rule_everywhere() {
        let src = "// xlint: allow-file(hot-lock): the lock is the control plane.\nuse std::sync::Mutex;\nfn f(m: &Mutex<u32>) {}\n";
        assert!(lint_file("exec/a.rs", src).is_empty());
    }

    #[test]
    fn waiver_for_one_rule_does_not_leak_to_others() {
        let src = "// xlint: allow-file(hot-lock): locks are fine here.\nfn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
        let vs = lint_file("exec/a.rs", src);
        assert_eq!(rules_of(&vs), ["hot-panic"]);
    }

    // ---- the real tree -------------------------------------------

    #[test]
    fn real_tree_is_lint_clean() {
        let src_root = repo_root().join("rust/src");
        let vs = lint_tree(&src_root).expect("lint the real tree");
        let listing: Vec<String> = vs.iter().map(|v| v.to_string()).collect();
        assert!(
            vs.is_empty(),
            "rust/src must lint clean:\n{}",
            listing.join("\n")
        );
    }
}
